"""Executor bridge: run a planned network end-to-end on any backend.

``run_net`` stages the input image into the ring, executes the NetPlan's
merged :class:`PoolProgram` on ``sim``/``jnp``/``pallas`` and fetches the
output; ``certify_net`` drives the sim oracle (raises
:class:`PoolClobberError` iff any cross-layer offset is unsafe);
``reference_forward`` computes the same network as a plain-XLA forward
pass with no pool mechanics — the float-tolerance ground truth for the
ring backends.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from ..core.executors import execute, run_program
from ..core.program import PoolProgram, resolve_activation
from .netplan import NetPlan


def _prog(plan) -> PoolProgram:
    return plan.program if isinstance(plan, NetPlan) else plan


def init_net_params(plan, key=None, dtype=jnp.float32) -> list:
    """Random, magnitude-controlled parameters for every op of the plan
    (weights scaled ~1/sqrt(fan_in) so deep nets stay in float range)."""
    program = _prog(plan)
    if key is None:
        key = jax.random.PRNGKey(0)
    gain = 2.0 ** 0.5  # He init: ReLU halves the variance
    params = []
    for op in program.ops:
        if op.kind in ("gemm", "conv_pw"):
            key, k1 = jax.random.split(key)
            w = jax.random.normal(k1, (op.d_in, op.d_out), dtype)
            params.append((w * gain / (op.d_in ** 0.5), None))
        elif op.kind == "conv_dw":
            key, k1 = jax.random.split(key)
            w = jax.random.normal(k1, (op.rs, op.rs, op.d_in), dtype)
            params.append((w / op.rs, None))
        elif op.kind == "ib_fused":
            key, k1, k2, k3 = jax.random.split(key, 4)
            w1 = jax.random.normal(k1, (op.d_in, op.d_mid), dtype) \
                / (op.d_in ** 0.5)
            wd = jax.random.normal(k2, (op.rs, op.rs, op.d_mid), dtype) \
                / op.rs
            w2 = jax.random.normal(k3, (op.d_mid, op.d_out), dtype) \
                / (op.d_mid ** 0.5)
            params.append((w1, wd, w2))
        elif op.kind == "fused_mlp":
            key, k1, k2, k3 = jax.random.split(key, 4)
            wg = jax.random.normal(k1, (op.d_in, op.d_ff), dtype) \
                / (op.d_in ** 0.5)
            wu = jax.random.normal(k2, (op.d_in, op.d_ff), dtype) \
                / (op.d_in ** 0.5)
            wd = jax.random.normal(k3, (op.d_ff, op.d_in), dtype) \
                / op.d_ff
            params.append((wg, wu, wd))
        else:
            params.append(None)
    return params


def _conv_ref(img, w, *, stride: int, pad_lo: int, h_out: int, w_out: int,
              groups: int = 1) -> jax.Array:
    """Independent conv oracle via ``lax.conv_general_dilated`` (NOT the
    executors' tap/gather formulation, so a shared indexing bug cannot
    cancel out).  High padding is chosen so the output is exactly
    ``ceil(h/stride)`` — the planner's 'same' convention."""
    h_in, w_in, _ = img.shape
    rs = w.shape[0]
    ph = (h_out - 1) * stride + rs - pad_lo - h_in
    pw = (w_out - 1) * stride + rs - pad_lo - w_in
    out = jax.lax.conv_general_dilated(
        img[None], w.astype(jnp.float32),
        window_strides=(stride, stride),
        padding=((pad_lo, ph), (pad_lo, pw)),
        dimension_numbers=("NHWC", "HWIO", "NHWC"),
        feature_group_count=groups)
    return out[0]


def reference_forward(plan, x: jax.Array, params) -> jax.Array:
    """Plain-XLA forward pass of the planned network (no pool).

    ``x`` is ``[rows, d]`` — the flattened input image.  Residual ``add``
    ops read the saved input of their source op, exactly as the ring
    executors read the held interval.
    """
    from ..core.rowsched import resample_src

    program = _prog(plan)
    saved: dict[int, jax.Array] = {}
    cur = x.astype(jnp.float32)
    for i, (op, p) in enumerate(zip(program.ops, params)):
        saved[i] = cur
        act = resolve_activation(op.activation)
        if op.kind in ("gemm", "conv_pw"):
            w, b = p if p[1] is not None else (p[0], jnp.zeros(op.d_out))
            wf = w.astype(jnp.float32)
            if op.kind == "conv_pw" and op.resample:
                # the nearest-grid adapter is gather-by-definition
                img = cur.reshape(op.h_in, op.w_in, op.d_in)
                ridx = [resample_src(r, op.h_in, op.h_out)
                        for r in range(op.h_out)]
                cidx = [resample_src(c, op.w_in, op.w_out)
                        for c in range(op.w_out)]
                sub = img[jnp.array(ridx)][:, jnp.array(cidx)]
                y = jnp.einsum("hwc,cd->hwd", sub, wf)
                cur = act(y + b).reshape(op.rows_out, op.d_out)
            elif op.kind == "conv_pw":
                img = cur.reshape(op.h_in, op.w_in, op.d_in)
                y = _conv_ref(img, wf.reshape(1, 1, op.d_in, op.d_out),
                              stride=op.stride, pad_lo=0,
                              h_out=op.h_out, w_out=op.w_out)
                cur = act(y + b).reshape(op.rows_out, op.d_out)
            else:
                cur = act(cur @ wf + b)
        elif op.kind == "conv_dw":
            w, b = p if p[1] is not None else (p[0], jnp.zeros(op.d_out))
            img = cur.reshape(op.h_in, op.w_in, op.d_in)
            y = _conv_ref(img,
                          w.astype(jnp.float32).reshape(op.rs, op.rs, 1,
                                                        op.d_in),
                          stride=op.stride, pad_lo=(op.rs - 1) // 2,
                          h_out=op.h_out, w_out=op.w_out,
                          groups=op.d_in)
            cur = act(y + b).reshape(op.rows_out, op.d_out)
        elif op.kind == "ib_fused":
            from ..kernels.inverted_bottleneck import \
                inverted_bottleneck_ref
            w1, wd, w2 = p
            a = cur.reshape(op.h_in, op.w_in, op.d_in)
            cur = inverted_bottleneck_ref(a, w1, wd, w2,
                                          residual=op.residual) \
                .astype(jnp.float32).reshape(op.rows_out, op.d_out)
        elif op.kind == "add":
            cur = cur + saved[op.aux_op]
        elif op.kind == "pool_avg":
            img = cur.reshape(op.h_in, op.w_in, op.d_in)
            cur = jnp.mean(img, axis=(0, 1))[None, :]
        elif op.kind == "fused_mlp":
            from ..kernels.ref import fused_mlp_ref
            wg, wu, wd = p
            cur = fused_mlp_ref(cur, wg, wu, wd, gated=op.gated,
                                residual=op.residual,
                                activation=op.activation) \
                .astype(jnp.float32)
        elif op.kind == "elementwise":
            cur = act(cur)
        else:
            raise NotImplementedError(op.kind)
    return cur


def run_net(plan, x: jax.Array, params, *, backend: str = "jnp",
            **kwargs) -> jax.Array:
    """Stage ``x`` at the plan's input pointer, execute every group
    through the one ring, fetch the network output."""
    program = _prog(plan)
    y, _pool = run_program(program, x, params, backend=backend, **kwargs)
    return y


def certify_net(plan):
    """Run the whole NetProgram through the SegmentPool clobber oracle.

    Returns the oracle (peak_live, reads/writes stats); raises
    :class:`repro.core.pool.PoolClobberError` iff any op's write lands on
    a segment some later op still needs — i.e. the cross-layer chaining
    is provably safe when this returns.
    """
    return execute(_prog(plan), backend="sim")
