"""Scheduling: lifetime analysis, operator reordering, fusion selection.

Reordering follows Liberis & Lane (PAPERS.md): among all topological
orders of the DAG, pick one minimising the peak of the tensor-lifetime
memory profile.  Exact search over orders is exponential, but with
memoisation on the *scheduled set* (the profile's future depends only on
which nodes ran, not in what order) MCUNet-class graphs — chains with
residual skips — collapse to a handful of states; a cap falls back to
the greedy order (smallest resulting live set first).

Fusion selection applies the paper's §7.3 exclusion rule: an
inverted-bottleneck module is fused iff the fused Eq.-(2) plan beats the
per-layer fallback (``vmcu_module_bytes``'s min); FC chains fuse iff the
streaming Eq.-(2) chain plan beats per-layer Eq.-(1) chaining.  Fused
*execution* additionally requires the Fig.-6 kernel's applicability
envelope (stride 1, one segment per pixel) — a byte-fused but strided
module still *reports* the fused footprint while *executing* unfused.
"""
from __future__ import annotations

import dataclasses
from typing import Sequence

from ..core.graph_planner import (ModuleConfig, plan_fc_chain,
                                  plan_inverted_bottleneck,
                                  plan_module_fallback)
from ..core.planner import plan_gemm
from ..core.vpool import SEG_WIDTH, segments_for
from .ir import Graph

# ---------------------------------------------------------------------------
# Lifetime analysis.
# ---------------------------------------------------------------------------


def tensor_lifetimes(graph: Graph, order: Sequence[str]
                     ) -> dict[str, tuple[int, int]]:
    """``{node_id: (birth_step, death_step)}`` of each node's OUTPUT
    tensor under ``order`` (death = last consumer's step; the graph
    output dies at the end)."""
    pos = {i: t for t, i in enumerate(order)}
    lifetimes = {}
    for i in order:
        cons = graph.consumers(i)
        death = max((pos[c] for c in cons), default=len(order) - 1)
        lifetimes[i] = (pos[i], death)
    return lifetimes


def peak_live_bytes(graph: Graph, order: Sequence[str]) -> int:
    """Peak of the tensor-level memory profile: at each step the node's
    inputs and output coexist, plus every tensor whose lifetime spans the
    step."""
    lt = tensor_lifetimes(graph, order)
    peak = 0
    for t, i in enumerate(order):
        live = 0
        for j, (b, d) in lt.items():
            alive = b <= t <= d
            # a node's output is also live while it is being produced
            if j == i:
                alive = True
            if alive:
                live += graph.nodes[j].out.nbytes
        # inputs being read at step t are live even if t is their death
        peak = max(peak, live)
    return peak


# ---------------------------------------------------------------------------
# Operator reordering.
# ---------------------------------------------------------------------------

def reorder(graph: Graph, *, max_states: int = 100_000
            ) -> tuple[list[str], int]:
    """Pick the topological order minimising peak live bytes.

    Exact memoised search over scheduled-sets (branch-and-bound on the
    running peak); falls back to the greedy order when the state budget
    is exhausted.  Returns ``(order, peak_live_bytes)``.
    """
    ids = list(graph.nodes)
    n = len(ids)
    idx = {i: k for k, i in enumerate(ids)}
    preds = {i: set(graph.nodes[i].inputs) for i in ids}
    succs = {i: graph.consumers(i) for i in ids}
    size = {i: graph.nodes[i].out.nbytes for i in ids}

    def live_after(scheduled: frozenset, extra: str) -> int:
        """Live bytes DURING the step that runs ``extra``: its inputs and
        output coexist with every tensor still awaiting a consumer —
        exactly :func:`peak_live_bytes`'s per-step accounting."""
        done = scheduled | {extra}
        total = 0
        for j in done:
            if (j == extra or j in preds[extra]
                    or any(c not in done for c in succs[j])
                    or not succs[j]):
                total += size[j]
        return total

    def ready(scheduled: frozenset) -> list[str]:
        return [i for i in ids
                if i not in scheduled and preds[i] <= scheduled]

    # greedy baseline (also the fallback)
    sched: frozenset = frozenset()
    greedy: list[str] = []
    while len(greedy) < n:
        cand = ready(sched)
        best = min(cand, key=lambda i: (live_after(sched, i), idx[i]))
        greedy.append(best)
        sched = sched | {best}
    bound = peak_live_bytes(graph, greedy)

    states = 0
    memo: dict[frozenset, int] = {}
    best_order: list[str] = greedy

    def dfs(scheduled: frozenset, order: list[str], peak: int) -> None:
        nonlocal states, bound, best_order
        if states > max_states:
            return
        if len(order) == n:
            if peak < bound:
                bound, best_order = peak, list(order)
            return
        seen = memo.get(scheduled)
        if seen is not None and seen <= peak:
            return
        memo[scheduled] = peak
        states += 1
        for i in sorted(ready(scheduled),
                        key=lambda i: (live_after(scheduled, i), idx[i])):
            step_peak = max(peak, live_after(scheduled, i))
            if step_peak >= bound:
                continue
            dfs(scheduled | {i}, order + [i], step_peak)

    dfs(frozenset(), [], 0)
    from ..obs.spans import set_attr
    set_attr(states_expanded=states, n_nodes=n,
             exhausted=states > max_states)
    return best_order, peak_live_bytes(graph, best_order)


# ---------------------------------------------------------------------------
# Fusion-group selection (paper §7.3 exclusion rule).
# ---------------------------------------------------------------------------

@dataclasses.dataclass(frozen=True)
class FusionGroup:
    """A run of scheduled nodes lowered as one planning unit.

    ``mcu_bytes`` is the byte-granular vMCU footprint by the paper's
    rule; ``fused_bytes_win`` records the rule's outcome and
    ``fused_exec`` whether execution uses the fused Fig.-6 kernel (rule
    win AND kernel applicability)."""

    name: str
    kind: str                 # module | resblock | mlp_chain | fc_chain
    #                           | single
    node_ids: tuple[str, ...]
    fused_bytes_win: bool = False
    fused_exec: bool = False
    mcu_bytes: int = 0
    te_bytes: int = 0
    hmcos_bytes: int = 0
    delta_bytes: int = 0      # byte-granular b_In - b_Out of the group


def _module_group(graph: Graph, ids: tuple[str, ...], cfg: ModuleConfig,
                  seg_width: int) -> FusionGroup:
    from ..core.graph_planner import (hmcos_module_bytes,
                                      tinyengine_module_bytes)

    fp = plan_inverted_bottleneck(cfg)
    fallback = plan_module_fallback(cfg)
    fused_win = fp.pool_bytes <= fallback
    fused_exec = (fused_win
                  and all(s == 1 for s in cfg.strides)
                  and segments_for(cfg.c_in, seg_width) == 1
                  and segments_for(cfg.c_out, seg_width) == 1)
    mcu = min(fp.pool_bytes, fallback)
    delta = fp.delta_bytes if fused_win else cfg.output_bytes
    return FusionGroup(name=cfg.name, kind="module", node_ids=ids,
                       fused_bytes_win=fused_win, fused_exec=fused_exec,
                       mcu_bytes=mcu, te_bytes=tinyengine_module_bytes(cfg),
                       hmcos_bytes=hmcos_module_bytes(cfg),
                       delta_bytes=delta)


def _resblock_group(graph: Graph, ids: tuple[str, ...]) -> FusionGroup:
    """Byte-granular plan of a ``block``-tagged residual run (ResNet
    basic block): the SAME spec lowering the executable planner uses
    (``netplan.resblock_specs`` — main-path convs with the block input
    held, optional shortcut projection reading the held tensor, post-add
    relu), solved at one byte per segment through ``plan_program``."""
    from ..core.program import plan_program
    from .netplan import resblock_specs

    specs = resblock_specs(graph, ids)
    tin = graph.in_tensor(ids[0])
    prog = plan_program(tin.rows, tin.d, specs, seg_width=1,
                        block_rows=None, elem_bytes=graph.elem_bytes)
    naive = prog.naive_bytes
    return FusionGroup(name=f"res[{ids[0]}..{ids[-1]}]", kind="resblock",
                       node_ids=tuple(ids), fused_bytes_win=True,
                       mcu_bytes=prog.pool_bytes, te_bytes=naive,
                       hmcos_bytes=naive,
                       delta_bytes=prog.input_ptr - prog.output_ptr)


def _single_group(graph: Graph, nid: str) -> FusionGroup:
    """Byte plan of a standalone node (adapter/spatial conv / pool / fc)."""
    import numpy as np

    from ..core.graph_planner import solve_stream_offset
    from ..core.rowsched import conv_k2d_pad

    n = graph.nodes[nid]
    if n.kind == "add":
        raise ValueError(
            f"{nid}: standalone residual adds are not plannable — tag the "
            "pw/dw/pw/add run with a module (or a ResNet run with a "
            "block) so the planner can hold the source tensor "
            "(ResidualAddSpec); free-form skip connections outside "
            "module/block groups are future work")
    tin = graph.in_tensor(nid)
    tout = n.out
    eb = graph.elem_bytes
    if n.kind == "conv_pw":
        p = np.arange(tout.rows, dtype=np.int64)
        op, oq = p // tout.w, p % tout.w
        if n.resample:
            sp, sq = (op * tin.h) // tout.h, (oq * tin.w) // tout.w
        else:
            sp, sq = op * n.stride, oq * n.stride
        read_start = (sp * tin.w + sq) * tin.d * eb
        write_end = (p + 1) * tout.d * eb
        delta = solve_stream_offset(write_end, read_start)
    elif n.kind in ("conv_dw", "conv_k2d"):
        # k-row/col halo window: output pixel (op, oq) still needs the
        # input from its window's low corner on — the Eq.-(2) frontier
        # the conv_k2d schedule widens vs the pointwise case
        pad = (conv_k2d_pad(n.rs, n.padding) if n.kind == "conv_k2d"
               else (n.rs - 1) // 2)
        p = np.arange(tout.rows, dtype=np.int64)
        op, oq = p // tout.w, p % tout.w
        sp = np.clip(op * n.stride - pad, 0, tin.h - 1)
        sq = np.clip(oq * n.stride - pad, 0, tin.w - 1)
        read_start = (sp * tin.w + sq) * tin.d * eb
        write_end = (p + 1) * tout.d * eb
        delta = solve_stream_offset(write_end, read_start)
    elif n.kind in ("conv_stream", "gru_cell"):
        # the frame/input row dies before any output write (delta 0);
        # the persistent state tensor coexists with both — the fourth
        # lifetime class, counted on top of the frame traffic
        state = (n.h_win * tin.w * tin.d * eb if n.kind == "conv_stream"
                 else tout.d * eb)
        mcu = max(tin.nbytes, tout.nbytes) + state
        naive = tin.nbytes + tout.nbytes + state
        return FusionGroup(name=nid, kind="single", node_ids=(nid,),
                           mcu_bytes=mcu, te_bytes=naive,
                           hmcos_bytes=naive, delta_bytes=0)
    elif n.kind == "avgpool":
        # output row written once, at the very end, over freed input
        delta = 0
    elif n.kind == "fc":
        delta = plan_gemm(tin.rows, tout.d * eb, tin.d * eb,
                          segment_bytes=1).delta
    else:   # flatten and friends: no bytes move
        return FusionGroup(name=nid, kind="single", node_ids=(nid,),
                           mcu_bytes=tin.nbytes, te_bytes=tin.nbytes,
                           hmcos_bytes=tin.nbytes, delta_bytes=0)
    mcu = max(tin.nbytes + delta, tout.nbytes)
    naive = tin.nbytes + tout.nbytes
    return FusionGroup(name=nid, kind="single", node_ids=(nid,),
                       mcu_bytes=mcu, te_bytes=naive, hmcos_bytes=naive,
                       delta_bytes=delta)


def _fc_chain_group(graph: Graph, ids: tuple[str, ...]) -> FusionGroup:
    eb = graph.elem_bytes
    tin = graph.in_tensor(ids[0])
    dims = [tin.d] + [graph.nodes[i].out.d for i in ids]
    m = tin.rows
    fused = plan_fc_chain(m, dims, elem_bytes=eb)
    unfused = max(plan_gemm(m, b * eb, a * eb, segment_bytes=1).pool_bytes
                  for a, b in zip(dims[:-1], dims[1:]))
    naive = max((a + b) * m * eb for a, b in zip(dims[:-1], dims[1:]))
    win = fused.pool_bytes <= unfused
    return FusionGroup(name=f"fc[{ids[0]}..{ids[-1]}]", kind="fc_chain",
                       node_ids=ids, fused_bytes_win=win,
                       mcu_bytes=min(fused.pool_bytes, unfused),
                       te_bytes=naive, hmcos_bytes=naive,
                       delta_bytes=fused.delta_bytes if win
                       else dims[-1] * m * eb)


def _mlp_chain_group(graph: Graph, ids: tuple[str, ...]) -> FusionGroup:
    tin = graph.in_tensor(ids[0])
    eb = graph.elem_bytes
    mcu = tin.nbytes            # in-place residual MLPs: x never moves
    naive = tin.nbytes * 2
    return FusionGroup(name=f"mlp[{ids[0]}..{ids[-1]}]", kind="mlp_chain",
                       node_ids=ids, fused_bytes_win=True, fused_exec=True,
                       mcu_bytes=mcu, te_bytes=naive, hmcos_bytes=naive,
                       delta_bytes=0)


def select_groups(graph: Graph, order: Sequence[str], *,
                  seg_width: int = SEG_WIDTH) -> list[FusionGroup]:
    """Partition a scheduled order into fusion groups.

    Module-tagged runs become ``module`` groups (fused by the exclusion
    rule); maximal runs of ``mlp`` / ``fc`` nodes become chain groups;
    everything else is a single-node group.  ``input``/``flatten`` nodes
    lower to nothing.
    """
    groups: list[FusionGroup] = []
    i = 0
    order = [o for o in order
             if graph.nodes[o].kind not in ("input", "flatten")]
    while i < len(order):
        nid = order[i]
        node = graph.nodes[nid]
        if node.module:
            tag = node.module
            j = i
            while j < len(order) and graph.nodes[order[j]].module == tag:
                j += 1
            ids = tuple(order[i:j])
            groups.append(_module_group(graph, ids, graph.modules[tag],
                                        seg_width))
            i = j
        elif node.block:
            tag = node.block
            j = i
            while j < len(order) and graph.nodes[order[j]].block == tag:
                j += 1
            ids = tuple(order[i:j])
            groups.append(_resblock_group(graph, ids))
            i = j
        elif node.kind in ("mlp", "fc"):
            kind = node.kind
            j = i
            while j < len(order) and graph.nodes[order[j]].kind == kind \
                    and not graph.nodes[order[j]].module:
                j += 1
            ids = tuple(order[i:j])
            if kind == "mlp":
                groups.append(_mlp_chain_group(graph, ids))
            elif len(ids) > 1:
                groups.append(_fc_chain_group(graph, ids))
            else:
                groups.append(_single_group(graph, ids[0]))
            i = j
        else:
            groups.append(_single_group(graph, nid))
            i += 1
    return groups
