"""Whole-network graph compiler (DESIGN.md §7).

Schedule, fuse and execute full DNNs on ONE VirtualPool:

  * ``ir``       — the DAG IR (conv/dw/pw, fc, mlp, elementwise, residual
                   add, pool/flatten nodes) + builders for the MCUNet
                   module tables and every ``configs/`` model.
  * ``schedule`` — lifetime analysis, operator reordering over
                   topological orders (branch/residual-aware) and fusion
                   group selection by the paper's exclusion rule.
  * ``netplan``  — the global planner: lowers scheduled groups through
                   ``plan_program()`` into one ring, chaining Eq.-(1)/(2)
                   offsets *across* group boundaries, and reports the
                   byte-granular MCU footprint vs the TinyEngine / HMCOS
                   baselines.
  * ``run``      — the executor bridge: stage, execute on sim/jnp/pallas,
                   fetch; plus the plain-XLA reference forward pass.

The deployment front door over this package is ``repro.compile(net,
target)`` (DESIGN.md §9); ``plan_net``/``quantize_net`` remain
importable here as deprecated shims over the driver's internals.
"""
from .ir import (Graph, Node, Tensor, build_ad_autoencoder, build_ds_cnn,
                 build_mcunet, build_mlp_tower, build_mobilenet_v1,
                 build_resnet8)
from .schedule import (FusionGroup, peak_live_bytes, reorder, select_groups,
                       tensor_lifetimes)
from .netplan import GroupPlan, NetPlan, plan_net
from .run import (QuantizedNet, certify_net, init_net_params,
                  quantize_net, quantized_agreement, reference_forward,
                  run_net, run_net_quantized)

__all__ = [
    "Graph", "Node", "Tensor", "build_ad_autoencoder", "build_ds_cnn",
    "build_mcunet", "build_mlp_tower", "build_mobilenet_v1",
    "build_resnet8",
    "FusionGroup", "peak_live_bytes", "reorder", "select_groups",
    "tensor_lifetimes",
    "GroupPlan", "NetPlan", "plan_net",
    "certify_net", "init_net_params", "reference_forward", "run_net",
    "QuantizedNet", "quantize_net", "quantized_agreement",
    "run_net_quantized",
]
