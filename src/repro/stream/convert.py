"""Feed-forward <-> streaming graph conversion.

A registered image/audio net consumes one whole ``h x w`` input window
per invocation.  :func:`to_streaming` rewrites it for per-frame
operation: the stem conv that consumes the graph input becomes a
``conv_stream`` node whose ``h_win``-row sliding window lives in the
segment ring as persistent state, and the graph input shrinks to the
``hop`` new rows arriving each step.  Everything downstream is
untouched — the stream step emits the SAME full-window stem output, so
once the window has filled (``h_win`` frames, zero-padded before that,
matching the reference conv's zero padding) every step reproduces the
one-shot net on the current window EXACTLY (bitwise for int8).

:func:`to_full` is the inverse, used by the equivalence tests to build
the one-shot twin of a streaming net over the same parameters.
"""
from __future__ import annotations

import dataclasses

from ..graph.ir import Graph, Tensor


def _single_stem(graph: Graph) -> tuple[str, str]:
    """The graph input id and its single conv consumer (the stem)."""
    in_id = graph.input_id()
    consumers = graph.consumers(in_id)
    if len(consumers) != 1:
        raise ValueError(
            f"streaming conversion needs exactly one consumer of the "
            f"graph input, {graph.name!r} has {len(consumers)}")
    return in_id, consumers[0]


def to_streaming(graph: Graph, *, hop: int = 1) -> Graph:
    """Convert a feed-forward net to per-frame streaming form.

    The stem must be a ``conv_k2d`` reading the graph input directly;
    its input height becomes the persistent window (``h_win``) and the
    new graph input is the ``hop`` rows appended per step."""
    in_id, stem_id = _single_stem(graph)
    stem = graph.nodes[stem_id]
    if stem.kind == "conv_stream":
        return graph          # already streaming
    if stem.kind != "conv_k2d":
        raise ValueError(
            f"streaming conversion needs a conv_k2d stem, "
            f"{stem_id!r} is {stem.kind!r}")
    tin = graph.nodes[in_id].out
    if not 0 < hop < tin.h:
        raise ValueError(f"hop must be in (0, {tin.h}), got {hop}")

    name = graph.name if graph.name.endswith("-stream") \
        else graph.name + "-stream"
    out = Graph(name, elem_bytes=graph.elem_bytes)
    out.modules = dict(graph.modules)
    frame = Tensor(rows=hop * tin.w, d=tin.d, h=hop, w=tin.w,
                   elem_bytes=tin.elem_bytes)
    for n in graph.nodes.values():
        if n.id == in_id:
            out.add(n.id, "input", [], frame)
        elif n.id == stem_id:
            out.nodes[n.id] = dataclasses.replace(
                n, kind="conv_stream", h_win=tin.h, hop=hop)
        else:
            out.nodes[n.id] = n
    out.validate()
    return out


def to_full(graph: Graph) -> Graph:
    """Convert a streaming net back to its one-shot feed-forward twin
    (the net :func:`to_streaming` started from, op list aligned 1:1)."""
    streams = [n for n in graph.nodes.values() if n.kind == "conv_stream"]
    if len(streams) != 1:
        raise ValueError(f"{graph.name!r} has {len(streams)} conv_stream "
                         "nodes; to_full needs exactly one")
    stem = streams[0]
    in_id = stem.inputs[0]
    tin = graph.nodes[in_id].out
    if graph.nodes[in_id].kind != "input":
        raise ValueError("conv_stream must read the graph input")

    name = graph.name[:-len("-stream")] \
        if graph.name.endswith("-stream") else graph.name + "-full"
    out = Graph(name, elem_bytes=graph.elem_bytes)
    out.modules = dict(graph.modules)
    window = Tensor(rows=stem.h_win * tin.w, d=tin.d, h=stem.h_win,
                    w=tin.w, elem_bytes=tin.elem_bytes)
    for n in graph.nodes.values():
        if n.id == in_id:
            out.add(n.id, "input", [], window)
        elif n.id == stem.id:
            out.nodes[n.id] = dataclasses.replace(
                n, kind="conv_k2d", h_win=0, hop=0)
        else:
            out.nodes[n.id] = n
    out.validate()
    return out
