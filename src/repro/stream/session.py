"""Streaming inference driver: persistent temporal state on the ring.

A :class:`StreamSession` owns ONE pool across invocations.  Each
``step(frame)`` stages only the new frame, executes the compiled
program — whose ``conv_stream`` / ``gru_cell`` ops shift their
ring-resident state and consume the frame — and fetches the step
output.  The state regions live wrap-free above the frame program's
linear extent (``core.program`` placement), so frame traffic can never
alias them; the static verifier certifies exactly that, and the sim
backend re-proves it step by step with live clobber detection.

Backends:

  * ``jnp`` / ``pallas`` — numeric execution on a persistent
    :class:`~repro.core.vpool.VirtualPool` (zero-initialized state ==
    the reference conv's zero padding, so outputs match the one-shot
    net exactly once the window has filled),
  * ``sim`` — the byte oracle: numerics-free, but every step replays
    the schedule through :class:`~repro.core.pool.SegmentPool` with the
    state records still live under their ``("state", i, j)`` owners —
    an N-step run is N independent clobber proofs plus the carried
    state-survival invariant.

``trace=True`` threads a :class:`repro.obs.RingTracer` through every
step (PR-7 observability: per-op wall times + byte traffic per frame);
the artifacts accumulate in :attr:`traces`.
"""
from __future__ import annotations

import jax.numpy as jnp

from ..core.executors import execute, run_program_sim
from ..core.vpool import VirtualPool


class StreamSession:
    """Reset/step driver over one compiled streaming net.

    Built by :meth:`repro.compile.CompiledNet.stream`; holds the pool
    (the persistent state) between ``step`` calls."""

    def __init__(self, compiled, *, backend: str = "jnp",
                 trace: bool = False):
        self.compiled = compiled
        self.backend = backend
        self.trace = trace
        self.quantized = compiled.quantized
        if self.quantized:
            self.program = compiled.qnet.program
            self.params = compiled.qnet.qparams
            self.in_scale = compiled.qnet.in_scale
            self.out_scale = compiled.qnet.out_scale
        else:
            if compiled.program.quantized:
                from ..compile.driver import CompileError

                raise CompileError(
                    "planner-only int8 compile: no qparams to stream "
                    "with — recompile with quantize=True")
            self.program = compiled.program
            self.params = compiled.ensure_params()
        if not any(op.state_segments for op in self.program.ops):
            raise ValueError(
                f"{compiled.net_name!r} has no stream state — compile "
                "with streaming=True (or a conv_stream/gru_cell graph)")
        self.traces: list = []
        self.reset()

    # -- state lifecycle ---------------------------------------------------
    def reset(self) -> "StreamSession":
        """Zero every state region and restart the step counter.

        Zero state is the semantic origin: a ``conv_stream`` window of
        zeros IS the reference conv's zero padding, so the first
        ``h_win`` steps reproduce a one-shot net seeing the partially
        filled window."""
        self.steps = 0
        if self.backend == "sim":
            self._pool = None      # run_program_sim pre-writes the state
        else:
            dtype = jnp.int8 if self.program.quantized else jnp.float32
            self._pool = VirtualPool.alloc(self.program.spec(dtype))
        return self

    # -- one frame ---------------------------------------------------------
    def step(self, frame=None):
        """Advance one frame.

        ``frame`` is ``[rows_in, d_in]`` (or anything reshapeable to
        it).  Float frames through a quantized net quantize on entry
        and dequantize on exit; an int8 frame is treated as already
        quantized and the raw int8 output is returned (the bitwise
        cross-backend contract).  The ``sim`` backend ignores numerics
        (pass ``frame=None``) and returns the oracle's counters."""
        program = self.program
        tracer = None
        if self.trace:
            from ..obs import RingTracer

            tracer = RingTracer()

        if self.backend == "sim":
            sim = run_program_sim(program, pool=self._pool, tracer=tracer)
            # the session consumes the step output; its record must die
            # before the next frame is staged over it
            last = program.ops[-1]
            for j in range(last.out_segments):
                sim.free(last.out_ptr + j, owner=(len(program.ops), j))
            self._pool = sim
            self.steps += 1
            self._finish_trace(tracer)
            return {"reads": sim.reads, "writes": sim.writes,
                    "frees": sim.frees, "peak_live": sim.peak_live,
                    "live": sim.live, "steps": self.steps}

        if frame is None:
            raise ValueError("array backends need a frame per step")
        first = program.ops[0]
        frame = jnp.asarray(frame).reshape(first.rows_in, program.in_dim)
        dequant = False
        if program.quantized:
            if frame.dtype != jnp.int8:
                from ..quant import QParams, quantize

                frame = quantize(frame, QParams(scale=self.in_scale))
                dequant = True
        else:
            frame = frame.astype(self._pool.array.dtype)
        pool = self._pool.stage_rows(frame, program.input_ptr)
        pool = execute(program, pool, self.params, backend=self.backend,
                       tracer=tracer)
        y = pool.fetch_rows(program.output_ptr, program.out_rows,
                            program.out_dim)
        self._pool = pool
        self.steps += 1
        self._finish_trace(tracer)
        if dequant:
            from ..quant import QParams, dequantize

            y = dequantize(y, QParams(scale=self.out_scale))
        return y

    def run(self, frames):
        """Feed ``frames`` (an iterable of per-step inputs) and return
        the last step's output — the streaming analogue of ``.run`` on
        the full window."""
        y = None
        for f in frames:
            y = self.step(f)
        return y

    # -- observability -----------------------------------------------------
    def _finish_trace(self, tracer) -> None:
        if tracer is None:
            return
        from ..obs import build_trace

        self.traces.append(build_trace(
            self.program, tracer=tracer, backend=self.backend,
            net=self.compiled.net_name, target=self.compiled.target.name))

    @property
    def state_segments(self) -> int:
        """Ring segments held by persistent state (the certified class)."""
        return sum(op.state_segments for op in self.program.ops)

    @property
    def state_bytes(self) -> int:
        return self.state_segments * self.program.seg_width \
            * self.program.elem_bytes
