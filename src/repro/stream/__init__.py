"""Streaming inference subsystem (DESIGN.md §14).

Persistent temporal state — ``conv_stream`` sliding windows and
``gru_cell`` hidden vectors — lives INSIDE the segment ring, wrap-free
above the frame program's linear extent, certified clobber-free across
an unbounded step horizon by the static verifier.

  * :func:`to_streaming` / :func:`to_full` — graph conversion,
  * :class:`StreamSession` — the reset/step driver
    (``repro.compile(...).stream()``).
"""
from .convert import to_full, to_streaming
from .session import StreamSession

__all__ = ["StreamSession", "to_full", "to_streaming"]
