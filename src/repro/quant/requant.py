"""Fixed-point requantization — TFLite/CMSIS-NN style, as pure jnp.

An int8 kernel accumulates in int32 and must map the accumulator back to
int8 at a *different* scale: ``q_out = round(acc * s_in * s_w / s_out)``.
Deployed runtimes (CMSIS-NN ``arm_nn_requantize``, TinyEngine, DORY)
encode the real multiplier as a Q31 fixed-point ``(multiplier, shift)``
pair and do the whole thing in integer arithmetic.  This module is that
layer:

  * :func:`quantize_multiplier` — encode a positive real scale as
    ``multiplier * 2**(shift - 31)`` with ``2**30 <= multiplier < 2**31``.
  * :func:`requantize` — ``RNE(acc * multiplier * 2**(shift - 31))``
    saturated to int8, in ONE rounding (round-to-nearest-even), exact
    over the full int32 accumulator range.

The product ``acc * multiplier`` needs 64 bits and neither Pallas-TPU
kernels nor default (x64-disabled) jax have an int64; the implementation
emulates the widening multiply and the rounding shift with int32/uint32
ops only (16-bit partial products + carry propagation — the same
decomposition an MCU's ``SMULL``/``SMMLA`` sequence performs), so it is
usable verbatim inside Pallas kernel bodies.
"""
from __future__ import annotations

import math

import jax.numpy as jnp

INT32_MIN = -(1 << 31)
INT32_MAX = (1 << 31) - 1

# Valid total right-shift range of the single-rounding requant:
# s = 31 - shift must lie in [1, 62] so ``half`` and the masks fit in
# the emulated 64-bit product.
SHIFT_MIN = -31
SHIFT_MAX = 30

_U16 = 0xFFFF
_U32 = 0xFFFFFFFF


def quantize_multiplier(real: float) -> tuple[int, int]:
    """Encode ``real > 0`` as ``(multiplier, shift)`` with
    ``real ~= multiplier * 2**(shift - 31)`` and ``multiplier`` a Q31
    mantissa in ``[2**30, 2**31)`` (TFLite's QuantizeMultiplier).

    ``real == 0`` encodes as ``(0, 0)``; ``shift`` outside
    ``[SHIFT_MIN, SHIFT_MAX]`` (a scale ratio beyond ``~2**30``) raises —
    such ratios cannot be requantized with a single rounding.
    """
    if real == 0.0:
        return 0, 0
    if real < 0.0 or not math.isfinite(real):
        raise ValueError(f"requant multiplier must be finite and >= 0, "
                         f"got {real}")
    frac, exp = math.frexp(real)          # real = frac * 2**exp
    m = round(frac * (1 << 31))
    if m == (1 << 31):                    # frac rounded up to 1.0
        m >>= 1
        exp += 1
    if not SHIFT_MIN <= exp <= SHIFT_MAX:
        raise ValueError(f"scale ratio {real} needs shift {exp}, outside "
                         f"[{SHIFT_MIN}, {SHIFT_MAX}]")
    return m, exp


def _mul64(a, b):
    """Full 64-bit product of int32 ``a * b`` as ``(hi int32, lo uint32)``
    using only 32-bit ops (16-bit partial products)."""
    au = a.astype(jnp.uint32)
    bu = b.astype(jnp.uint32)
    al, ah = au & _U16, au >> 16
    bl, bh = bu & _U16, bu >> 16
    ll = al * bl
    lh = al * bh
    hl = ah * bl
    hh = ah * bh
    cross = (ll >> 16) + (lh & _U16) + (hl & _U16)
    hi_u = hh + (lh >> 16) + (hl >> 16) + (cross >> 16)
    # signed high word: mulhs(a,b) = mulhu(a,b) - (a<0)*b - (b<0)*a
    hi_u = hi_u - jnp.where(a < 0, bu, jnp.uint32(0))
    hi_u = hi_u - jnp.where(b < 0, au, jnp.uint32(0))
    return hi_u.astype(jnp.int32), au * bu


def _shr64_rne(hi, lo, s):
    """``(hi:lo) >> s`` (arithmetic, 64-bit) rounding to nearest, ties to
    even; ``s`` int32 in ``[1, 62]``.  Returns ``(hi, lo)`` of the
    quotient."""
    one = jnp.uint32(1)
    s = s.astype(jnp.int32)
    s1 = jnp.clip(s, 1, 31)               # clamped shift operands: every
    s2 = jnp.clip(s - 32, 0, 31)          # jnp shift stays within [0,31]
    su1 = s1.astype(jnp.uint32)
    su2 = s2.astype(jnp.uint32)
    hi_u = hi.astype(jnp.uint32)

    # remainder == half detection on the PRE-offset value (tie test)
    mask_lo = jnp.where(s >= 32, jnp.uint32(_U32), (one << su1) - one)
    mask_hi = jnp.where(s <= 32, jnp.uint32(0),
                        (one << jnp.clip(s - 32, 0, 31).astype(jnp.uint32))
                        - one)
    half_lo = jnp.where(s <= 32,
                        one << jnp.clip(s - 1, 0, 31).astype(jnp.uint32),
                        jnp.uint32(0))
    half_hi = jnp.where(s <= 32, jnp.uint32(0),
                        one << jnp.clip(s - 33, 0, 31).astype(jnp.uint32))
    tie = ((lo & mask_lo) == half_lo) & ((hi_u & mask_hi) == half_hi)

    # 64-bit add of half (carry out of the low word)
    lo2 = lo + half_lo
    carry = (lo2 < lo).astype(jnp.int32)
    hi2 = hi + half_hi.astype(jnp.int32) + carry
    hi2_u = hi2.astype(jnp.uint32)

    # 64-bit arithmetic shift right by s
    lo_a = (lo2 >> su1) | (hi2_u << (jnp.uint32(32) - su1))
    hi_a = hi2 >> s1
    lo_b = (hi2 >> s2).astype(jnp.uint32)
    hi_b = hi2 >> 31
    res_lo = jnp.where(s <= 31, lo_a, lo_b)
    res_hi = jnp.where(s <= 31, hi_a, hi_b)

    # ties rounded up by the half-offset: pull odd results back down
    dec = (tie & ((res_lo & one) == one)).astype(jnp.uint32)
    new_lo = res_lo - dec
    borrow = ((dec == one) & (res_lo == jnp.uint32(0))).astype(jnp.int32)
    return res_hi - borrow, new_lo


def requantize_i32(acc, multiplier, shift):
    """``RNE(acc * multiplier * 2**(shift-31))`` as int32, saturated to
    ``[-2**24, 2**24]`` (well clear of the int8 range, so the final int8
    clamp downstream is unaffected) — the form residual adds use, two
    requantized operands summed before the final clamp.

    ``acc`` int32 (any shape); ``multiplier``/``shift`` int32 scalars or
    arrays broadcastable against it (per-channel requant broadcasts a
    trailing ``[c]`` axis).  Pure jnp — usable inside Pallas kernels.
    """
    acc = jnp.asarray(acc, jnp.int32)
    multiplier = jnp.asarray(multiplier, jnp.int32)
    shift = jnp.asarray(shift, jnp.int32)
    acc, multiplier, shift = jnp.broadcast_arrays(acc, multiplier, shift)
    hi, lo = _mul64(acc, multiplier)
    q_hi, q_lo = _shr64_rne(hi, lo, jnp.int32(31) - shift)
    # saturate the 64-bit quotient to int32, then to the working range
    lo_i = q_lo.astype(jnp.int32)
    fits = q_hi == (lo_i >> 31)
    v = jnp.where(fits, lo_i,
                  jnp.where(q_hi < 0, jnp.int32(INT32_MIN),
                            jnp.int32(INT32_MAX)))
    return jnp.clip(v, -(1 << 24), 1 << 24)


def requantize(acc, multiplier, shift, *, zero_point=0):
    """``clamp(RNE(acc * multiplier * 2**(shift-31)) + zero_point)`` to
    int8 — ONE round-to-nearest-even over the exact 64-bit product, then
    saturation (the behaviour the hypothesis property test pins against
    the exact ``Fraction`` reference).  Same broadcasting / purity notes
    as :func:`requantize_i32`, which does all the arithmetic."""
    v = requantize_i32(acc, multiplier, shift) + jnp.int32(zero_point)
    return jnp.clip(v, -128, 127).astype(jnp.int8)


def gru_update(gx, gh, h, d_h: int):
    """fp32 hard-gate GRU update (gate order z, r, n) — the ONE
    definition the jnp executor, the reference oracle and the Pallas
    kernel share.

    ``gx = x @ w + b`` and ``gh = h @ u`` are ``[..., 3*d_h]`` gate
    pre-activations; gates are piecewise linear — ``hard_sigmoid(t) =
    clip(t/4 + 0.5, 0, 1)``, ``hard_tanh(t) = clip(t, -1, 1)`` — so the
    int8 twin (:func:`gru_update_q12`) is a pure fixed-point pipeline
    that agrees bitwise across backends."""
    z = jnp.clip(0.25 * (gx[..., :d_h] + gh[..., :d_h]) + 0.5, 0.0, 1.0)
    r = jnp.clip(0.25 * (gx[..., d_h:2 * d_h] + gh[..., d_h:2 * d_h])
                 + 0.5, 0.0, 1.0)
    n = jnp.clip(gx[..., 2 * d_h:] + r * gh[..., 2 * d_h:], -1.0, 1.0)
    return (1.0 - z) * n + z * h


def gru_update_q12(gx, gh, h_q7, d_h: int):
    """Fixed-point twin of :func:`gru_update` (CMSIS-NN discipline).

    ``gx``/``gh`` are int32 gate pre-activations in Q12 (scale 1/4096;
    the Q12 bias is already folded into ``gx``); ``h_q7`` is the hidden
    state at the FIXED Q7 state scale 1/128 (the pool-resident int8
    layout).  hard_sigmoid lands in ``[0, 4096]`` Q12, hard_tanh in
    ``[-4096, 4096]``, and the blend ``(1-z)*n + z*h`` resolves at Q7
    with a single ``>> 12``.  Pre-activations saturate at ``±2**18``
    (far past every gate's linear region) so all products fit int32.
    Pure jnp — usable verbatim inside Pallas kernels.
    """
    lim = 1 << 18
    gx = jnp.clip(jnp.asarray(gx, jnp.int32), -lim, lim)
    gh = jnp.clip(jnp.asarray(gh, jnp.int32), -lim, lim)
    h_q7 = jnp.asarray(h_q7, jnp.int32)
    z = jnp.clip(((gx[..., :d_h] + gh[..., :d_h] + 2) >> 2) + 2048,
                 0, 4096)
    r = jnp.clip(((gx[..., d_h:2 * d_h] + gh[..., d_h:2 * d_h] + 2) >> 2)
                 + 2048, 0, 4096)
    n = jnp.clip(gx[..., 2 * d_h:]
                 + ((r * gh[..., 2 * d_h:] + 2048) >> 12), -4096, 4096)
    n_q7 = jnp.clip((n + 16) >> 5, -128, 127)
    hp = (z * h_q7 + (4096 - z) * n_q7 + 2048) >> 12
    return jnp.clip(hp, -128, 127).astype(jnp.int8)


def act_i32(acc, activation):
    """Int32-domain activation between accumulate and requantize.

    With symmetric scales (``zero_point == 0``) relu commutes with
    requantization, so clamping the accumulator at zero is exact;
    anything nonlinear beyond relu has no single-multiplier int8 form
    and is rejected at quantize time — this is the ONE definition both
    the jnp executor ops and the Pallas kernels use, so the two
    backends cannot drift."""
    if activation in (None, "identity"):
        return acc
    if activation == "relu":
        return jnp.maximum(acc, 0)
    raise NotImplementedError(
        f"activation {activation!r} has no int8 path (relu/None only)")
