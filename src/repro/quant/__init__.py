"""Int8 quantized execution subsystem (DESIGN.md §8).

``qtensor`` — symmetric per-tensor/per-channel int8 params + calibration;
``requant`` — TFLite/CMSIS-NN fixed-point requantization as pure jnp.
The network-level bridge (``quantize_net`` / ``run_net_quantized``) lives
in :mod:`repro.graph.run`; the int8 executor paths in
:mod:`repro.core.executors` and :mod:`repro.kernels.quantized`.
"""
from .qtensor import (QMAX, QMIN, QParams, calibrate, dequantize, quantize,
                      quantize_bias, requant_pair, requant_scalar)
from .requant import (INT32_MAX, INT32_MIN, SHIFT_MAX, SHIFT_MIN, act_i32,
                      quantize_multiplier, requantize, requantize_i32)

__all__ = [
    "QMAX", "QMIN", "QParams", "calibrate", "dequantize", "quantize",
    "quantize_bias", "requant_pair", "requant_scalar",
    "INT32_MAX", "INT32_MIN", "SHIFT_MAX", "SHIFT_MIN", "act_i32",
    "quantize_multiplier", "requantize", "requantize_i32",
]
