"""Symmetric int8 quantization parameters + calibration.

The scheme is the deployable MCUNet/DORY form:

  * activations — per-tensor symmetric (``zero_point == 0``), scale
    calibrated as ``amax(|x|)/127`` over the float reference forward;
  * weights — per-output-channel symmetric, so each output channel gets
    its own requant ``(multiplier, shift)`` pair;
  * biases — int32 at the accumulator scale ``s_in * s_w[c]``.

Everything here is host-side (numpy) planning; the in-kernel arithmetic
lives in :mod:`repro.quant.requant`.
"""
from __future__ import annotations

import dataclasses

import jax.numpy as jnp
import numpy as np

from .requant import quantize_multiplier

QMIN, QMAX = -127, 127   # symmetric: -128 is never produced by quantize()
SCALE_FLOOR = 1e-8       # all-zero tensors/channels quantize at scale 1e-8


@dataclasses.dataclass(frozen=True)
class QParams:
    """Symmetric quantization parameters of one tensor.

    ``scale`` is a float for per-tensor params or a ``[c]`` numpy array
    for per-channel (``axis`` names the channel axis of the tensor).
    ``zero_point`` is always 0 in this scheme; it is carried so the
    record stays honest about the affine form."""

    scale: object
    axis: int | None = None
    zero_point: int = 0

    @property
    def per_channel(self) -> bool:
        return self.axis is not None

    def _bcast(self, ndim: int) -> np.ndarray:
        s = np.asarray(self.scale, np.float64)
        if self.axis is None:
            return s
        shape = [1] * ndim
        shape[self.axis] = -1
        return s.reshape(shape)


def calibrate(x, axis: int | None = None) -> QParams:
    """Symmetric scale(s) from float data: ``amax(|x|) / 127``.

    ``axis=None`` gives one per-tensor scale; an integer gives one scale
    per slice of that axis (per-channel weights)."""
    x = np.asarray(x, np.float64)
    if axis is None:
        amax = float(np.abs(x).max()) if x.size else 0.0
        return QParams(scale=max(amax / QMAX, SCALE_FLOOR), axis=None)
    reduce_axes = tuple(i for i in range(x.ndim) if i != axis)
    amax = np.abs(x).max(axis=reduce_axes)
    return QParams(scale=np.maximum(amax / QMAX, SCALE_FLOOR), axis=axis)


def quantize(x, qp: QParams):
    """Float -> int8 (round-to-nearest-even, clamped to [-127, 127])."""
    x = np.asarray(x, np.float64)
    q = np.rint(x / qp._bcast(x.ndim))
    return jnp.asarray(np.clip(q, QMIN, QMAX).astype(np.int8))


def dequantize(q, qp: QParams):
    """Int8 -> float32."""
    q = np.asarray(q, np.float64)
    return jnp.asarray((q * qp._bcast(q.ndim)).astype(np.float32))


def quantize_bias(b, in_scale: float, w_qp: QParams) -> jnp.ndarray:
    """Bias at the int32 accumulator scale ``s_in * s_w[c]``."""
    s = np.asarray(w_qp.scale, np.float64) * float(in_scale)
    bq = np.rint(np.asarray(b, np.float64) / s)
    return jnp.asarray(np.clip(bq, -(1 << 30), 1 << 30).astype(np.int32))


def requant_pair(in_scale: float, w_qp: QParams,
                 out_scale: float) -> tuple[jnp.ndarray, jnp.ndarray]:
    """Per-channel ``(multiplier[c], shift[c])`` int32 arrays encoding
    ``s_in * s_w[c] / s_out``."""
    sw = np.atleast_1d(np.asarray(w_qp.scale, np.float64))
    mults, shifts = zip(*(quantize_multiplier(float(in_scale) * float(s)
                                              / float(out_scale))
                          for s in sw))
    return (jnp.asarray(np.array(mults, np.int32)),
            jnp.asarray(np.array(shifts, np.int32)))


def requant_scalar(ratio: float) -> tuple[int, int]:
    """Scalar ``(multiplier, shift)`` for a plain scale ratio (residual
    add operands, average-pool normalization)."""
    return quantize_multiplier(float(ratio))
