"""Pool-occupancy timeline: memory-over-time from the solved plan.

Replays the SAME live-record model the static verifier proves safety
with (one record per live tensor: the held network input, every op's
surviving output, residual sources until their consuming ``add``) and
derives, per op:

  * the output interval being streamed into the ring,
  * every record live while the op runs (its input included — frees
    happen as the op's read frontier passes, so the input is live at
    the op's start),
  * ``span_segs`` — the extent of the occupied window (output interval
    union live records, unwrapped pointers).

The timeline's watermark is ``max(span_segs)`` — for a solved plan this
equals ``program.pool_segments`` exactly (the ring is tight: some op's
occupied window spans the whole allocation), so ``watermark_bytes ==
program.pool_bytes`` is an invariant tests and the CLI smoke gate
assert.  Per-tensor residency intervals (born/died op indices) fall out
of the same replay.  Pure arithmetic on memoized schedules — deriving a
timeline costs nothing beyond the planning the program already paid.
"""
from __future__ import annotations

import dataclasses

from ..core.rowsched import schedule_for_op


@dataclasses.dataclass(frozen=True)
class Residency:
    """Lifetime of one pool-resident tensor.

    ``tensor`` 0 is the staged network input; tensor ``i`` is the output
    of op ``i - 1``.  ``born`` is the op index that produced it (-1 for
    the staged input); ``died`` is the op index whose execution freed it
    (``n_ops`` for tensors surviving the whole program)."""

    tensor: int
    ptr: int
    segments: int
    born: int
    died: int

    def to_dict(self) -> dict:
        return dataclasses.asdict(self)


@dataclasses.dataclass(frozen=True)
class OpOccupancy:
    """Ring occupancy while one op executes."""

    index: int
    out_lo: int                       # unwrapped output interval
    out_hi: int
    live: tuple                       # ((ptr, segments), ...) records
    live_segs: int                    # resident segments at op start
    span_segs: int                    # extent of the occupied window

    def to_dict(self) -> dict:
        return {"index": self.index, "out_lo": self.out_lo,
                "out_hi": self.out_hi,
                "live": [list(rec) for rec in self.live],
                "live_segs": self.live_segs,
                "span_segs": self.span_segs}


@dataclasses.dataclass(frozen=True)
class PoolTimeline:
    n_segments: int
    pool_segments: int
    seg_bytes: int
    ops: tuple
    residencies: tuple

    @property
    def watermark_segments(self) -> int:
        return max(o.span_segs for o in self.ops)

    @property
    def watermark_bytes(self) -> int:
        return self.watermark_segments * self.seg_bytes

    def live_curve(self) -> list[int]:
        """Resident segments at the start of each op (length n_ops)."""
        return [o.live_segs for o in self.ops]

    def to_dict(self) -> dict:
        return {"n_segments": self.n_segments,
                "pool_segments": self.pool_segments,
                "seg_bytes": self.seg_bytes,
                "watermark_segments": self.watermark_segments,
                "watermark_bytes": self.watermark_bytes,
                "ops": [o.to_dict() for o in self.ops],
                "residencies": [r.to_dict() for r in self.residencies]}


def pool_timeline(program) -> PoolTimeline:
    """Derive the occupancy timeline of a planned program (no execution).

    The record update rule mirrors the verifier's replay exactly: an
    op's input record (or, for branch ops, the held record of op
    ``in_op``) dies with the op unless ``hold_input``; the residual
    source dies at its consuming ``add``; the op's output becomes record
    ``i + 1``.
    """
    first = program.ops[0]
    seg_bytes = program.seg_width * program.elem_bytes

    records: dict[int, tuple[int, int, int]] = {
        0: (first.in_ptr, first.in_segments, -1)}   # (ptr, segs, born)
    occupancies: list[OpOccupancy] = []
    residencies: list[Residency] = []

    def _kill(tensor: int, died: int) -> None:
        ptr, segs, born = records.pop(tensor)
        residencies.append(Residency(tensor=tensor, ptr=ptr,
                                     segments=segs, born=born, died=died))

    for i, op in enumerate(program.ops):
        sched = schedule_for_op(op, program.seg_width,
                                m_rows=program.m_rows)
        out_tot = sum(len(rows) for rows in sched.writes) \
            * sched.out_chunk
        iown = op.in_op if op.in_op >= 0 else i
        live = tuple((ptr, segs) for ptr, segs, _ in records.values())
        lo = min([op.out_ptr] + [p for p, _ in live])
        hi = max([op.out_ptr + out_tot] + [p + s for p, s in live])
        occupancies.append(OpOccupancy(
            index=i, out_lo=op.out_ptr, out_hi=op.out_ptr + out_tot,
            live=live, live_segs=sum(s for _, s in live),
            span_segs=hi - lo))
        if not op.hold_input and iown in records:
            _kill(iown, i)
        if op.aux_op >= 0 and op.aux_op in records:
            _kill(op.aux_op, i)
        records[i + 1] = (op.out_ptr, out_tot, i)

    n_ops = len(program.ops)
    for tensor in sorted(records):
        _kill(tensor, n_ops)
    residencies.sort(key=lambda r: r.tensor)
    return PoolTimeline(n_segments=program.n_segments,
                        pool_segments=program.pool_segments,
                        seg_bytes=seg_bytes, ops=tuple(occupancies),
                        residencies=tuple(residencies))
