"""``vmcu-trace`` — ring telemetry as a console script.

    vmcu-trace ds-cnn                         # compile + sim-trace + render
    vmcu-trace vww.trace.json                 # render a saved trace
    vmcu-trace vww.plan.json                  # trace a saved plan artifact
    vmcu-trace ds-cnn --backend jnp           # measured per-op wall times
    vmcu-trace ds-cnn --chrome out.json       # Perfetto / chrome://tracing
    vmcu-trace --diff a.trace.json b.trace.json
    vmcu-trace --smoke                        # self-contained CI gate

Renders the ASCII memory-map timeline (one row per op: output interval,
live tensors, free slots, watermark at the bottom) plus the traffic
totals; ``--save`` writes the schema-versioned trace JSON, ``--chrome``
the Chrome trace-event export.  ``--diff`` compares two traces: exit 1
iff they differ structurally (wall-time drift alone never gates).

``--smoke`` needs no inputs: it compiles MCUNet-VWW for cortex-m4
(planner-only, ``certify="static"``), traces one sim-oracle execution,
and asserts the telemetry invariants — measured byte counts equal the
safety certificate's reads/writes BIT-EXACTLY, the occupancy watermark
equals the plan's ``pool_bytes``, and the saved trace + Chrome export
round-trip — then leaves ``vww.trace.json`` / ``vww.chrome.json`` on
disk for CI artifact upload.  Exit 0/1, 2 on usage errors.
"""
from __future__ import annotations

import argparse
import json
import sys


def _sim_trace(program, *, net=None, target=None, spans=None):
    from ..core.executors import execute
    from .tracer import RingTracer, build_trace

    tracer = RingTracer()
    execute(program, backend="sim", tracer=tracer)
    return build_trace(program, tracer=tracer, net=net, target=target,
                       spans=spans)


def _trace_from_spec(spec: str, *, target: str, dtype: str | None,
                     backend: str):
    """Resolve a positional spec to a TraceArtifact.

    A readable JSON file is a saved trace (rendered as-is) or a saved
    plan artifact (traced now); anything else is a registered net name
    (compiled for ``target`` first).
    """
    from pathlib import Path

    from .artifact import TRACE_SCHEMA, TraceArtifact

    if Path(spec).is_file():
        with open(spec) as f:
            payload = json.load(f)
        if payload.get("schema") == TRACE_SCHEMA:
            return TraceArtifact.from_dict(payload, source=spec)
        from ..compile.driver import CompiledNet

        cn = CompiledNet.load(spec)
        if backend == "sim":
            return _sim_trace(cn.program, net=cn.net_name,
                              target=cn.target.name, spans=cn.spans)
        return cn.profile(backend=backend)

    from ..compile.driver import compile as _compile

    cn = _compile(spec, target, dtype=dtype, quantize=backend != "sim",
                  certify="static")
    if backend == "sim":
        return _sim_trace(cn.program, net=cn.net_name,
                          target=cn.target.name, spans=cn.spans)
    return cn.profile(backend=backend)


def _render(art, width: int) -> None:
    print(art.ascii_timeline(width=width))
    t = art.totals
    line = (f"traffic: {t['bytes_loaded']} B loaded / "
            f"{t['bytes_stored']} B stored, {t['macs']} MACs "
            f"({t['arithmetic_intensity']:.2f} MAC/B)")
    if "wall_us" in t:
        line += f", {t['wall_us'] / 1e3:.2f} ms wall"
    print(line)
    if art.spans:
        print("compile pipeline:")
        for s in art.spans:
            _print_span(s, 1)


def _print_span(s: dict, depth: int) -> None:
    attrs = "".join(f" {k}={v}" for k, v in s.get("attrs", {}).items())
    print(f"{'  ' * depth}{s['name']}: {s['seconds'] * 1e3:.1f} ms{attrs}")
    for c in s.get("children", []):
        _print_span(c, depth + 1)


def _diff(path_a: str, path_b: str) -> int:
    from .artifact import TraceArtifact, diff_traces

    d = diff_traces(TraceArtifact.load(path_a), TraceArtifact.load(path_b))
    for line in d["structural"]:
        print(f"STRUCT {line}")
    for line in d["wall"]:
        print(f"wall   {line}")
    if d["structural"]:
        print(f"{len(d['structural'])} structural difference(s)")
        return 1
    print("traces structurally identical"
          + (f" ({len(d['wall'])} wall-time rows)" if d["wall"] else ""))
    return 0


def _smoke() -> int:
    """The CI gate: trace VWW through the sim oracle and assert the
    telemetry invariants against the independent safety certificate."""
    from ..compile.driver import compile as _compile
    from .artifact import TraceArtifact

    cn = _compile("mcunet-5fps-vww", "cortex-m4", quantize=False,
                  certify="static")
    art = _sim_trace(cn.program, net=cn.net_name, target=cn.target.name,
                     spans=cn.spans)
    cert = cn.certificate

    # measured bytes == certificate reads/writes, bit-exactly
    seg_bytes = cn.program.seg_width * cn.program.elem_bytes
    t = art.totals
    if (t["bytes_loaded"] != cert["reads"] * seg_bytes
            or t["bytes_stored"] != cert["writes"] * seg_bytes
            or t["sim"]["reads"] != cert["reads"]
            or t["sim"]["writes"] != cert["writes"]):
        print(f"smoke FAILED: traced traffic {t['segs_read']}r/"
              f"{t['segs_written']}w != certificate {cert['reads']}r/"
              f"{cert['writes']}w", file=sys.stderr)
        return 1
    print(f"traffic OK: {cert['reads']} segment reads / "
          f"{cert['writes']} writes, measured == certified")

    # occupancy watermark == the plan's pool allocation
    if art.watermark_bytes != cn.program.pool_bytes:
        print(f"smoke FAILED: watermark {art.watermark_bytes} B != "
              f"pool_bytes {cn.program.pool_bytes} B", file=sys.stderr)
        return 1
    print(f"watermark OK: {art.watermark_bytes} B == plan pool_bytes")

    # the artifact + Chrome export must round-trip
    art.save("vww.trace.json")
    reloaded = TraceArtifact.load("vww.trace.json")
    if reloaded.canonical() != art.canonical():
        print("smoke FAILED: trace artifact does not round-trip",
              file=sys.stderr)
        return 1
    chrome = art.to_chrome_trace()
    with open("vww.chrome.json", "w") as f:
        json.dump(chrome, f)
    with open("vww.chrome.json") as f:
        chrome = json.load(f)
    xs = [e for e in chrome.get("traceEvents", []) if e.get("ph") == "X"]
    if not xs or any("dur" not in e or "ts" not in e for e in xs):
        print("smoke FAILED: Chrome export has no well-formed complete "
              "events", file=sys.stderr)
        return 1
    print(f"exports OK: vww.trace.json + vww.chrome.json "
          f"({len(xs)} complete events)")
    print(art.ascii_timeline().splitlines()[-1])
    print("vmcu-trace smoke OK")
    return 0


def main(argv: "list[str] | None" = None) -> int:
    ap = argparse.ArgumentParser(
        prog="vmcu-trace",
        description="Trace vMCU ring executions: per-op byte/MAC "
                    "counters, pool-occupancy timelines, wall times and "
                    "compile-pipeline spans — rendered as an ASCII "
                    "memory map or exported for Perfetto.")
    ap.add_argument("spec", nargs="?",
                    help="a net name (compiled then traced), a saved "
                         "plan artifact, or a saved .trace.json")
    ap.add_argument("--target", default="cortex-m4",
                    help="target descriptor for net-name specs "
                         "(default: cortex-m4)")
    ap.add_argument("--dtype", default=None,
                    help="pool dtype override (default: the target's)")
    ap.add_argument("--backend", default="sim",
                    choices=("sim", "jnp", "pallas"),
                    help="executor to trace (default: sim — measured "
                         "segment traffic, no numerics)")
    ap.add_argument("--width", type=int, default=64,
                    help="ASCII timeline width in columns (default 64)")
    ap.add_argument("--save", metavar="PATH",
                    help="write the trace artifact JSON")
    ap.add_argument("--chrome", metavar="PATH",
                    help="write Chrome trace-event JSON (Perfetto)")
    ap.add_argument("--diff", nargs=2, metavar=("A", "B"),
                    help="compare two saved traces; exit 1 iff they "
                         "differ structurally")
    ap.add_argument("--smoke", action="store_true",
                    help="CI gate: sim-trace MCUNet-VWW and assert the "
                         "telemetry invariants against the certificate")
    args = ap.parse_args(argv)

    if args.smoke:
        if args.spec or args.diff:
            print("--smoke is self-contained; drop the other arguments",
                  file=sys.stderr)
            return 2
        return _smoke()
    if args.diff:
        if args.spec:
            print("--diff takes exactly two traces; drop the spec",
                  file=sys.stderr)
            return 2
        return _diff(*args.diff)
    if not args.spec:
        ap.print_usage(file=sys.stderr)
        print("vmcu-trace: need a net name, plan artifact or trace "
              "(or --diff / --smoke)", file=sys.stderr)
        return 2

    try:
        art = _trace_from_spec(args.spec, target=args.target,
                               dtype=args.dtype, backend=args.backend)
    except (OSError, ValueError, KeyError) as e:
        print(f"{args.spec}: ERROR {e}", file=sys.stderr)
        return 1
    _render(art, args.width)
    if args.save:
        print(f"trace written to {art.save(args.save)}")
    if args.chrome:
        with open(args.chrome, "w") as f:
            json.dump(art.to_chrome_trace(), f)
        print(f"chrome trace written to {args.chrome}")
    return 0


if __name__ == "__main__":
    sys.exit(main())
