"""Observability: ring telemetry, occupancy timelines, pipeline spans.

Three layers (DESIGN.md §12):

  * :mod:`~repro.obs.counters` / :mod:`~repro.obs.timeline` — static
    per-op byte/MAC counters and the pool-occupancy timeline, derived
    from the same row schedules the planner and verifier share (trace
    totals equal the safety certificate's reads/writes bit-exactly),
  * :mod:`~repro.obs.tracer` — :class:`RingTracer` measurement hooks in
    all three executors (``execute(..., tracer=...)``), zero-cost when
    absent,
  * :mod:`~repro.obs.spans` — nested timed spans for the compile
    pipeline (and any other instrumented extent), no-ops without an
    installed collector.

``vmcu-trace`` (:mod:`~repro.obs.cli`) renders/exports the resulting
schema-versioned :class:`TraceArtifact`.
"""
from .artifact import TRACE_SCHEMA, TraceArtifact, diff_traces
from .counters import (OpCounters, op_counters, op_macs, op_requants,
                       program_totals)
from .spans import Span, SpanCollector, collect, set_attr, span
from .timeline import PoolTimeline, pool_timeline
from .tracer import RingTracer, build_trace

__all__ = [
    "TRACE_SCHEMA", "TraceArtifact", "diff_traces",
    "OpCounters", "op_counters", "op_macs", "op_requants",
    "program_totals",
    "Span", "SpanCollector", "collect", "set_attr", "span",
    "PoolTimeline", "pool_timeline",
    "RingTracer", "build_trace",
]
