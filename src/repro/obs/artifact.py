"""Serializable trace artifacts + their render/export surfaces.

A :class:`TraceArtifact` is the schema-versioned JSON form of one traced
execution (or of a purely static plan trace): geometry, per-(op) events
with byte/MAC/requant counters and optional measured wall times, the
pool-occupancy timeline, whole-program totals, and any compile-pipeline
spans that rode along.  Surfaces:

  * :meth:`save` / :meth:`load`     — JSON beside the plan artifact,
  * :meth:`to_chrome_trace`         — Chrome trace-event JSON (Perfetto:
    ring ops as complete events, pool occupancy as counter tracks,
    compile passes as a nested span track),
  * :meth:`ascii_timeline`          — terminal memory-map timeline,
  * :meth:`canonical`               — the trace with every wall-time
    field stripped (what determinism tests and golden files pin),
  * :func:`diff_traces`             — structural + wall comparison.
"""
from __future__ import annotations

import dataclasses
import json

TRACE_SCHEMA = "vmcu-trace/1"
_WALL_KEYS = ("wall_us",)


@dataclasses.dataclass
class TraceArtifact:
    schema: str
    net: str | None
    backend: str | None
    target: str | None
    geometry: dict
    events: list
    timeline: dict
    totals: dict
    spans: list = dataclasses.field(default_factory=list)

    # -- payload -----------------------------------------------------------
    def to_dict(self) -> dict:
        return {"schema": self.schema, "net": self.net,
                "backend": self.backend, "target": self.target,
                "geometry": dict(self.geometry),
                "events": [dict(e) for e in self.events],
                "timeline": self.timeline, "totals": dict(self.totals),
                "spans": list(self.spans)}

    def canonical(self) -> dict:
        """The payload with every wall-time field stripped — two traced
        runs of one plan are identical under this form, and it is what
        the golden file pins."""
        payload = self.to_dict()
        for key in _WALL_KEYS:
            payload["totals"].pop(key, None)
        payload["events"] = [
            {k: v for k, v in e.items() if k not in _WALL_KEYS}
            for e in payload["events"]]
        payload["spans"] = []      # pipeline spans are all wall time
        return payload

    @property
    def watermark_bytes(self) -> int:
        return self.timeline["watermark_bytes"]

    # -- persistence -------------------------------------------------------
    def save(self, path: str) -> str:
        with open(path, "w") as f:
            json.dump(self.to_dict(), f, indent=1)
        return path

    @classmethod
    def load(cls, path: str) -> "TraceArtifact":
        with open(path) as f:
            payload = json.load(f)
        return cls.from_dict(payload, source=path)

    @classmethod
    def from_dict(cls, payload: dict, source: str = "<dict>"
                  ) -> "TraceArtifact":
        if payload.get("schema") != TRACE_SCHEMA:
            raise ValueError(
                f"{source}: trace schema {payload.get('schema')!r} != "
                f"supported {TRACE_SCHEMA!r}")
        return cls(schema=payload["schema"], net=payload.get("net"),
                   backend=payload.get("backend"),
                   target=payload.get("target"),
                   geometry=payload["geometry"],
                   events=payload["events"],
                   timeline=payload["timeline"],
                   totals=payload["totals"],
                   spans=payload.get("spans", []))

    # -- Chrome trace-event export ----------------------------------------
    def to_chrome_trace(self) -> dict:
        """Chrome trace-event JSON (open in Perfetto / chrome://tracing).

        Ring ops are ``ph:"X"`` complete events on pid 1; measured wall
        times set the timebase when present, otherwise schedule steps
        serve as pseudo-microseconds (the shape of the timeline is the
        schedule either way).  Pool occupancy (live segments, occupied
        span) rides as ``ph:"C"`` counter tracks; compile-pipeline spans
        (when the trace carries them) as a nested track on pid 2.
        """
        ev: list[dict] = [
            {"ph": "M", "name": "process_name", "pid": 1, "tid": 1,
             "args": {"name": f"vmcu ring ({self.backend or 'static'})"}},
            {"ph": "M", "name": "process_name", "pid": 2, "tid": 1,
             "args": {"name": "vmcu compile pipeline"}},
        ]
        occ = {o["index"]: o for o in self.timeline["ops"]}
        ts = 0.0
        for e in self.events:
            dur = float(e.get("wall_us", max(e.get("steps", 1), 1)))
            args = {k: v for k, v in e.items() if k != "name"}
            ev.append({"ph": "X", "name": e["name"], "cat": "ring",
                       "pid": 1, "tid": 1, "ts": ts, "dur": dur,
                       "args": args})
            o = occ.get(e.get("index"))
            if o is not None:
                ev.append({"ph": "C", "name": "pool_live_segments",
                           "pid": 1, "tid": 1, "ts": ts,
                           "args": {"live": o["live_segs"]}})
                ev.append({"ph": "C", "name": "pool_span_segments",
                           "pid": 1, "tid": 1, "ts": ts,
                           "args": {"span": o["span_segs"]}})
            ts += dur

        def emit_span(s: dict, tid: int) -> None:
            ev.append({"ph": "X", "name": s["name"], "cat": "compile",
                       "pid": 2, "tid": tid,
                       "ts": s.get("start_s", 0.0) * 1e6,
                       "dur": s["seconds"] * 1e6,
                       "args": dict(s.get("attrs", {}))})
            for c in s.get("children", []):
                emit_span(c, tid)

        for s in self.spans:
            emit_span(s, 1)
        meta = {"net": self.net, "backend": self.backend,
                "target": self.target, "schema": self.schema}
        return {"traceEvents": ev, "displayTimeUnit": "ms",
                "otherData": meta}

    # -- ASCII memory-map timeline ----------------------------------------
    def ascii_timeline(self, width: int = 64) -> str:
        """Render the ring as one row per op: ``#`` the output interval
        being streamed, ``=`` live resident tensors, ``.`` free slots.
        Watermark line at the bottom (== the plan's pool_bytes)."""
        n = self.geometry["n_segments"]
        width = min(width, n)
        seg_bytes = self.timeline["seg_bytes"]
        names = {e.get("index"): e["name"] for e in self.events}
        lines = [f"ring memory map — {self.net or 'program'} "
                 f"({self.backend or 'static'}), {n} segments x "
                 f"{seg_bytes} B   # output  = live  . free"]
        for o in self.timeline["ops"]:
            slots = ["."] * n
            for ptr, segs in o["live"]:
                for s in range(ptr, ptr + segs):
                    slots[s % n] = "="
            for s in range(o["out_lo"], o["out_hi"]):
                slots[s % n] = "#"
            if n > width:                   # bucket; '#' > '=' > '.'
                chars = []
                for j in range(width):
                    lo, hi = j * n // width, max((j + 1) * n // width,
                                                 j * n // width + 1)
                    bucket = slots[lo:hi]
                    chars.append("#" if "#" in bucket
                                 else "=" if "=" in bucket else ".")
                row = "".join(chars)
            else:
                row = "".join(slots)
            name = names.get(o["index"], f"op[{o['index']}]")
            lines.append(f"op {o['index']:>3} {name:<14} |{row}| "
                         f"live {o['live_segs']:>6} "
                         f"span {o['span_segs']:>6}/{n}")
        wm = self.timeline["watermark_segments"]
        lines.append(f"watermark: {wm}/{self.geometry['pool_segments']} "
                     f"pool segments = {self.watermark_bytes} B "
                     f"(plan pool_bytes {self.geometry['pool_bytes']} B)")
        return "\n".join(lines)


def diff_traces(a: TraceArtifact, b: TraceArtifact) -> dict:
    """Compare two traces: ``structural`` lists every non-wall-time
    difference (geometry, counters, occupancy — empty iff the two runs
    executed the same plan the same way); ``wall`` lists per-op wall-time
    deltas where both sides measured one."""
    structural: list[str] = []

    def walk(pa, pb, path: str) -> None:
        if isinstance(pa, dict) and isinstance(pb, dict):
            for k in sorted(set(pa) | set(pb)):
                if k not in pa or k not in pb:
                    structural.append(f"{path}.{k}: only in "
                                      f"{'second' if k not in pa else 'first'}")
                else:
                    walk(pa[k], pb[k], f"{path}.{k}")
        elif isinstance(pa, list) and isinstance(pb, list):
            if len(pa) != len(pb):
                structural.append(f"{path}: length {len(pa)} != {len(pb)}")
            else:
                for i, (va, vb) in enumerate(zip(pa, pb)):
                    walk(va, vb, f"{path}[{i}]")
        elif pa != pb:
            structural.append(f"{path}: {pa!r} != {pb!r}")

    walk(a.canonical(), b.canonical(), "trace")

    wall: list[str] = []
    wa = {e.get("index"): e["wall_us"] for e in a.events if "wall_us" in e}
    wb = {e.get("index"): e["wall_us"] for e in b.events if "wall_us" in e}
    names = {e.get("index"): e["name"] for e in a.events}
    for i in sorted(set(wa) & set(wb)):
        d = wb[i] - wa[i]
        rel = d / wa[i] if wa[i] else 0.0
        wall.append(f"{names.get(i, i)}: {wa[i]:.1f}us -> {wb[i]:.1f}us "
                    f"({rel:+.0%})")
    return {"structural": structural, "wall": wall}
