"""Nested timed spans — the compile-pipeline side of the telemetry layer.

A :class:`SpanCollector` is installed for a dynamic extent (a ``compile``
call, a benchmark section); inside it, ``with span(name, **attrs):``
records a nested timed span and ``set_attr(**attrs)`` annotates the
innermost open one (B&B states expanded, calibration batches, cache
hits).  With NO collector installed, :func:`span` is a no-op context
manager and :func:`set_attr` returns immediately — instrumented code
pays one contextvar lookup, nothing else, so spans are safe to leave in
hot paths like the scheduler's search loop.

The collector is a :mod:`contextvars` variable, so concurrent compiles
(threads, async) each see their own span tree.
"""
from __future__ import annotations

import contextlib
import contextvars
import dataclasses
import time
from typing import Any, Iterator

_ACTIVE: contextvars.ContextVar["SpanCollector | None"] = \
    contextvars.ContextVar("vmcu_span_collector", default=None)


@dataclasses.dataclass
class Span:
    """One timed region: wall seconds, free-form attributes, children."""

    name: str
    seconds: float = 0.0
    start_s: float = 0.0       # offset from the collector's epoch
    attrs: dict = dataclasses.field(default_factory=dict)
    children: list = dataclasses.field(default_factory=list)

    def to_dict(self) -> dict:
        return {"name": self.name, "seconds": self.seconds,
                "start_s": self.start_s, "attrs": dict(self.attrs),
                "children": [c.to_dict() for c in self.children]}

    @classmethod
    def from_dict(cls, d: dict) -> "Span":
        return cls(name=d["name"], seconds=d["seconds"],
                   start_s=d.get("start_s", 0.0),
                   attrs=dict(d.get("attrs", {})),
                   children=[cls.from_dict(c)
                             for c in d.get("children", [])])


class SpanCollector:
    """Accumulates a forest of spans for one instrumented extent."""

    def __init__(self) -> None:
        self.spans: list[Span] = []
        self._stack: list[Span] = []
        self._epoch = time.perf_counter()

    def to_dicts(self) -> list[dict]:
        return [s.to_dict() for s in self.spans]


@contextlib.contextmanager
def collect(collector: SpanCollector | None = None
            ) -> Iterator[SpanCollector]:
    """Install a collector for the enclosed extent (a fresh one when not
    given; pass your own to accumulate several extents into one tree)."""
    col = collector if collector is not None else SpanCollector()
    token = _ACTIVE.set(col)
    try:
        yield col
    finally:
        _ACTIVE.reset(token)


@contextlib.contextmanager
def span(name: str, **attrs: Any) -> Iterator[Span | None]:
    """Record a timed span when a collector is active; no-op otherwise."""
    col = _ACTIVE.get()
    if col is None:
        yield None
        return
    s = Span(name=name, attrs=dict(attrs))
    s.start_s = time.perf_counter() - col._epoch
    parent = col._stack[-1] if col._stack else None
    (parent.children if parent is not None else col.spans).append(s)
    col._stack.append(s)
    t0 = time.perf_counter()
    try:
        yield s
    finally:
        s.seconds = time.perf_counter() - t0
        col._stack.pop()


def set_attr(**attrs: Any) -> None:
    """Annotate the innermost open span (no-op without a collector)."""
    col = _ACTIVE.get()
    if col is not None and col._stack:
        col._stack[-1].attrs.update(attrs)


def active() -> bool:
    """True iff a collector is installed (for cheap guard checks)."""
    return _ACTIVE.get() is not None
