"""Static per-op traffic/compute counters — the metrics registry.

Every counter is derived from the SAME row schedules the planner solved
its offsets with and the verifier replays (``core.rowsched``), so the
schedule-level convention is shared with the safety certificate:

  * ``segs_read``    = read events x in_chunk + aux events x aux_chunk,
  * ``segs_written`` = write events x out_chunk,

and the program totals — with the input staging writes and the output
survival reads added (:func:`program_totals`) — equal the ``reads`` /
``writes`` fields of the static/sim certificate BIT-EXACTLY (asserted
in tests and in the ``vmcu-trace --smoke`` CI gate).

MAC counts are nominal (zero-padding taps of spatial convs included,
matching the usual MACs-per-inference convention); requant counts are
requantize invocations at element granularity (``add`` rescales both
operands, so it counts twice its output elements) and are zero for
float programs.
"""
from __future__ import annotations

import dataclasses

from ..core.rowsched import schedule_for_op


@dataclasses.dataclass(frozen=True)
class OpCounters:
    """Schedule-derived traffic/compute counters of one PoolOp."""

    index: int
    kind: str
    steps: int
    segs_read: int
    segs_written: int
    bytes_loaded: int
    bytes_stored: int
    macs: int
    requants: int

    @property
    def bytes_moved(self) -> int:
        return self.bytes_loaded + self.bytes_stored

    @property
    def arithmetic_intensity(self) -> float:
        """MACs per byte moved through the ring (0 for pure-move ops)."""
        moved = self.bytes_moved
        return self.macs / moved if moved else 0.0

    def to_dict(self) -> dict:
        return {"index": self.index, "kind": self.kind,
                "steps": self.steps, "segs_read": self.segs_read,
                "segs_written": self.segs_written,
                "bytes_loaded": self.bytes_loaded,
                "bytes_stored": self.bytes_stored, "macs": self.macs,
                "requants": self.requants}


def op_macs(op, m_rows: int) -> int:
    """Nominal multiply-accumulates of one op (0 for move/reduce ops)."""
    rows = op.rows_in or m_rows
    if op.kind == "gemm":
        return rows * op.d_in * op.d_out
    if op.kind == "conv_pw":
        return op.h_out * op.w_out * op.d_in * op.d_out
    if op.kind == "conv_dw":
        return op.h_out * op.w_out * op.rs * op.rs * op.d_in
    if op.kind == "conv_k2d":
        return op.h_out * op.w_out * op.rs * op.rs * op.d_in * op.d_out
    if op.kind == "ib_fused":
        return op.h_in * op.w_in * (op.d_in * op.d_mid
                                    + op.rs * op.rs * op.d_mid
                                    + op.d_mid * op.d_out)
    if op.kind == "fused_mlp":
        return rows * op.d_in * op.d_ff * (3 if op.gated else 2)
    return 0   # add / pool_avg / elementwise: no MACs


def op_requants(op, m_rows: int, *, quantized: bool) -> int:
    """Requantize invocations (element granularity); 0 for float."""
    if not quantized:
        return 0
    rows_out = op.rows_out or m_rows
    if op.kind == "add":
        return 2 * (op.rows_in or m_rows) * op.d_in
    return rows_out * op.d_out


def op_counters(program) -> list[OpCounters]:
    """Per-op counters of a planned program (pure schedule arithmetic —
    nothing executes; memoized schedule builders make this O(ops))."""
    seg_bytes = program.seg_width * program.elem_bytes
    out = []
    for i, op in enumerate(program.ops):
        sched = schedule_for_op(op, program.seg_width,
                                m_rows=program.m_rows)
        n_read = sum(len(rows) for rows in sched.reads)
        n_aux = (sum(len(rows) for rows in sched.aux_reads)
                 if sched.aux_reads is not None else 0)
        segs_read = n_read * sched.in_chunk + n_aux * sched.aux_chunk
        segs_written = sum(len(rows) for rows in sched.writes) \
            * sched.out_chunk
        out.append(OpCounters(
            index=i, kind=op.kind, steps=sched.steps,
            segs_read=segs_read, segs_written=segs_written,
            bytes_loaded=segs_read * seg_bytes,
            bytes_stored=segs_written * seg_bytes,
            macs=op_macs(op, program.m_rows),
            requants=op_requants(op, program.m_rows,
                                 quantized=program.quantized)))
    return out


def stage_segments(program) -> int:
    """Segments written to stage the network input into the ring."""
    return program.ops[0].in_segments


def fetch_segments(program) -> int:
    """Segments read to fetch the surviving network output."""
    return program.ops[-1].out_segments


def program_totals(program, counters: list[OpCounters] | None = None
                   ) -> dict:
    """Whole-program totals in the certificate's counting convention:
    ``segs_read``/``segs_written`` (and their byte forms) include the
    input staging writes and the output survival reads, so they equal
    the verifier certificate's ``reads``/``writes`` bit-exactly."""
    if counters is None:
        counters = op_counters(program)
    seg_bytes = program.seg_width * program.elem_bytes
    stage, fetch = stage_segments(program), fetch_segments(program)
    segs_read = sum(c.segs_read for c in counters) + fetch
    segs_written = sum(c.segs_written for c in counters) + stage
    macs = sum(c.macs for c in counters)
    bytes_moved = (segs_read + segs_written) * seg_bytes
    return {
        "segs_read": segs_read,
        "segs_written": segs_written,
        "bytes_loaded": segs_read * seg_bytes,
        "bytes_stored": segs_written * seg_bytes,
        "macs": macs,
        "requants": sum(c.requants for c in counters),
        "arithmetic_intensity": macs / bytes_moved if bytes_moved else 0.0,
    }
