"""RingTracer — step-level measurement hooks for the executors.

A :class:`RingTracer` is handed to ``execute(..., tracer=...)`` (or
``CompiledNet.run(x, trace=True)``).  Executors call :meth:`record` with
per-op wall seconds (array backends synchronize per op so the numbers
are real device time, not dispatch time); the ``sim`` backend
additionally snapshots the SegmentPool access counters around every op
(:meth:`record_sim`) — a *measured* read/write/free count that tests
assert equals the schedule-derived :mod:`counters` bit-exactly.

``tracer=None`` (the default) is the zero-cost path: the ``jnp``
executor runs its pre-existing whole-program jit (bit-identical output,
no per-op sync), and the other backends skip every tracer call site.

:func:`build_trace` fuses the static counters/timeline with whatever a
tracer measured into one :class:`~repro.obs.artifact.TraceArtifact`.
"""
from __future__ import annotations

import dataclasses

from .counters import (fetch_segments, op_counters, program_totals,
                       stage_segments)
from .timeline import pool_timeline


@dataclasses.dataclass
class RingTracer:
    """Mutable measurement sink for one traced execution."""

    backend: str | None = None
    wall_s: dict = dataclasses.field(default_factory=dict)
    sim_counts: dict = dataclasses.field(default_factory=dict)
    sim_summary: dict | None = None

    def record(self, op_index: int, seconds: float) -> None:
        self.wall_s[op_index] = seconds

    def record_sim(self, op_index: int, *, reads: int, writes: int,
                   frees: int, live: int) -> None:
        self.sim_counts[op_index] = {"reads": reads, "writes": writes,
                                     "frees": frees, "live": live}

    def finish_sim(self, sim) -> None:
        self.sim_summary = {"peak_live": sim.peak_live,
                            "reads": sim.reads, "writes": sim.writes,
                            "frees": sim.frees}


def build_trace(program, *, tracer: RingTracer | None = None,
                backend: str | None = None, net: str | None = None,
                target: str | None = None, spans: list | None = None):
    """Assemble a TraceArtifact for ``program``.

    Works with no tracer at all (a purely static trace: schedule-derived
    counters + occupancy timeline, no wall times) — that is what the
    plan-only surfaces (`vmcu-trace` on an artifact) use.
    """
    from .artifact import TRACE_SCHEMA, TraceArtifact

    counters = op_counters(program)
    timeline = pool_timeline(program)
    totals = program_totals(program, counters)
    totals["watermark_bytes"] = timeline.watermark_bytes

    seg_bytes = program.seg_width * program.elem_bytes
    events: list[dict] = [{
        "name": "stage_input", "kind": "stage", "index": -1,
        "segs_read": 0, "segs_written": stage_segments(program),
        "bytes_loaded": 0,
        "bytes_stored": stage_segments(program) * seg_bytes,
    }]
    for c in counters:
        ev = c.to_dict()
        ev["name"] = f"{c.kind}[{c.index}]"
        if tracer is not None and c.index in tracer.wall_s:
            ev["wall_us"] = tracer.wall_s[c.index] * 1e6
        if tracer is not None and c.index in tracer.sim_counts:
            ev["sim"] = dict(tracer.sim_counts[c.index])
        events.append(ev)
    events.append({
        "name": "fetch_output", "kind": "fetch",
        "index": len(program.ops),
        "segs_read": fetch_segments(program), "segs_written": 0,
        "bytes_loaded": fetch_segments(program) * seg_bytes,
        "bytes_stored": 0,
    })

    if tracer is not None and tracer.wall_s:
        totals["wall_us"] = sum(tracer.wall_s.values()) * 1e6
    if tracer is not None and tracer.sim_summary is not None:
        totals["sim"] = dict(tracer.sim_summary)

    from ..compile.artifact import program_sha256

    geometry = {
        "n_ops": len(program.ops),
        "m_rows": program.m_rows,
        "seg_width": program.seg_width,
        "block_rows": program.block_rows,
        "n_segments": program.n_segments,
        "pool_segments": program.pool_segments,
        "elem_bytes": program.elem_bytes,
        "dtype": program.dtype,
        "pool_bytes": program.pool_bytes,
        "physical_pool_bytes": program.physical_pool_bytes,
        "program_sha256": program_sha256(program),
    }
    backend = backend or (tracer.backend if tracer is not None else None)
    return TraceArtifact(schema=TRACE_SCHEMA, net=net, backend=backend,
                         target=target, geometry=geometry, events=events,
                         timeline=timeline.to_dict(), totals=totals,
                         spans=list(spans) if spans else [])
