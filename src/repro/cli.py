"""``vmcu-compile`` — the deployment driver as a console script.

    vmcu-compile mcunet-5fps-vww --target cortex-m4 --dtype int8 \
                 --emit-c out/ --save vww.plan.json

Compiles a registered net for a target (build -> schedule -> plan ->
budget -> quantize -> certify), prints the report, and optionally emits
the intrinsic-C units and/or the JSON plan artifact.  ``--smoke`` is
the CI gate: compile MCUNet-VWW, enforce the SRAM budget, and diff the
emitted ring-geometry C against the committed goldens.
"""
from __future__ import annotations

import argparse
import pathlib
import sys


def _print_report(rep: dict) -> None:
    passes = rep.pop("passes", [])
    cert = rep.pop("certificate", None)
    for k, v in rep.items():
        if isinstance(v, float):
            v = f"{v:.4f}"
        print(f"  {k:28s} {v}")
    if cert is not None:
        print(f"  {'certificate':28s} {cert}")
    for name, secs, note in passes:
        print(f"    pass {name:9s} {secs:8.3f}s  {note}")


def _diff_goldens(units: dict[str, str], golden_dir: pathlib.Path) -> int:
    """Compare emitted units against the committed goldens; return the
    number of drifted/missing files (0 = clean)."""
    bad = 0
    names = {p.name for p in golden_dir.glob("*.c")}
    for name, src in units.items():
        golden = golden_dir / name
        if not golden.exists():
            print(f"  MISSING golden {golden}", file=sys.stderr)
            bad += 1
        elif golden.read_text() != src:
            print(f"  DRIFT vs golden {golden}", file=sys.stderr)
            bad += 1
    for stale in names - set(units):
        print(f"  STALE golden {golden_dir / stale} (no longer emitted)",
              file=sys.stderr)
        bad += 1
    return bad


def main(argv=None) -> int:
    import repro

    ap = argparse.ArgumentParser(
        prog="vmcu-compile",
        description="One-call vMCU deployment: net in, segment-ring plan "
                    "+ MCU kernels out.")
    ap.add_argument("net", nargs="?", default=None,
                    help="registered net name (default mcunet-5fps-vww) "
                         "or artifact path with --from-artifact")
    ap.add_argument("--target", default=None,
                    help="target descriptor ("
                         f"{', '.join(repro.list_targets())}); default "
                         "host-sim, or cortex-m4 under --smoke")
    ap.add_argument("--dtype", default=None,
                    help="pool dtype (default: the target's)")
    ap.add_argument("--emit-c", metavar="DIR",
                    help="write one intrinsic-C unit per op into DIR")
    ap.add_argument("--save", metavar="FILE",
                    help="write the solved plan artifact (JSON)")
    ap.add_argument("--from-artifact", action="store_true",
                    help="treat NET as a saved artifact and load it "
                         "instead of compiling")
    ap.add_argument("--certify", choices=("sim", "static"), default="sim",
                    help="certification mode: replay the sim clobber "
                         "oracle, or statically prove clobber-freedom "
                         "(repro.analysis; falls back to sim outside "
                         "the decidable fragment)")
    ap.add_argument("--no-certify", action="store_true",
                    help="skip the certification pass entirely")
    ap.add_argument("--no-budget", action="store_true",
                    help="record the SRAM verdict without gating")
    ap.add_argument("--partial", default="off", metavar="MODE",
                    help="partial execution: 'auto' slices over-budget "
                         "fusion groups until the deployable ring fits "
                         "SRAM, an integer forces that many slices on "
                         "the pinning group, 'off' (default) keeps the "
                         "hard budget gate")
    ap.add_argument("--no-quantize", action="store_true",
                    help="int8 planner-only compile: solve the ring "
                         "and budgets without calibrating qparams")
    ap.add_argument("--list-targets", action="store_true")
    ap.add_argument("--list-nets", action="store_true")
    ap.add_argument("--smoke", action="store_true",
                    help="CI gate: compile MCUNet-VWW for the target, "
                         "enforce the SRAM budget, diff emitted "
                         "ring-geometry C against --golden-dir")
    ap.add_argument("--golden-dir", default="tests/golden/vww",
                    help="golden C directory for --smoke")
    args = ap.parse_args(argv)

    if args.list_targets:
        for name in repro.list_targets():
            t = repro.get_target(name)
            print(f"{name:12s} {t.cpu}  sram={t.sram_bytes} "
                  f"flash={t.flash_bytes} idiom={t.requant_idiom} "
                  f"dtype={t.default_dtype}")
        return 0
    if args.list_nets:
        print("\n".join(repro.available_nets()))
        return 0

    # --smoke pins the whole configuration (net AND int8 MCU target) so
    # the gate is self-contained; otherwise host-sim is the default.
    target = args.target or ("cortex-m4" if args.smoke else "host-sim")
    if args.smoke and args.net not in (None, "mcunet-5fps-vww"):
        print(f"--smoke gates MCUNet-VWW only; drop the {args.net!r} "
              "argument (or run without --smoke)", file=sys.stderr)
        return 2

    if args.from_artifact:
        if args.net is None:
            print("--from-artifact needs an artifact path",
                  file=sys.stderr)
            return 2
        cn = repro.load(args.net)
        print(f"loaded {args.net} ({cn.net_name} for {cn.target.name})")
    else:
        net = args.net or "mcunet-5fps-vww"
        partial = args.partial
        if partial not in ("off", "auto"):
            try:
                partial = int(partial)
            except ValueError:
                print(f"--partial must be 'off', 'auto' or an integer "
                      f"slice count, got {partial!r}", file=sys.stderr)
                return 2
        try:
            cn = repro.compile(net, target=target, dtype=args.dtype,
                               certify=(False if args.no_certify
                                        else args.certify),
                               check_budget=not args.no_budget,
                               quantize=not args.no_quantize,
                               partial=partial)
        except repro.SRAMBudgetError as e:
            print(f"SRAM budget gate FAILED: {e}", file=sys.stderr)
            return 2
    _print_report(cn.report())

    if args.emit_c:
        units = cn.emit_c(args.emit_c)
        print(f"wrote {len(units)} C units to {args.emit_c}")
    if args.save:
        cn.save(args.save)
        print(f"wrote plan artifact {args.save}")

    if args.smoke:
        golden_dir = pathlib.Path(args.golden_dir)
        if not golden_dir.is_dir():
            print(f"golden dir {golden_dir} not found (run from the repo "
                  "root or pass --golden-dir)", file=sys.stderr)
            return 2
        units = cn.emit_c(geometry_only=True, name="vww")
        bad = _diff_goldens(units, golden_dir)
        if bad:
            print(f"smoke FAILED: {bad} golden mismatches (regenerate "
                  "with tests/golden/regen.py if intentional)",
                  file=sys.stderr)
            return 1
        print(f"smoke OK: SRAM gate passed, {len(units)} C units match "
              f"{golden_dir}")
        cn.emit_c()  # exercise the full requant-table emission too
    return 0


if __name__ == "__main__":
    sys.exit(main())
