"""End-to-end driver: train a ~100M-parameter gemma-style LM for a few
hundred steps with the full production stack — AdamW, remat, microbatching,
atomic+async checkpointing, deterministic restart-safe data.

Run:  PYTHONPATH=src python examples/train_lm.py [--steps 300] [--tiny]
"""
import argparse
import dataclasses

from repro.configs.base import ModelConfig
from repro.launch.train import train_loop

# ~100M params: 14L, d=640, GQA 8/4, d_ff=2560 GeGLU, 32k vocab
LM_100M = ModelConfig(
    name="lm-100m", family="lm",
    n_layers=14, d_model=640, n_heads=8, n_kv_heads=4, head_dim=80,
    d_ff=2560, vocab=32_768,
    pattern=("local", "global"), window=256,
    mlp="geglu", tie_embeddings=True,
    shard_mode="fsdp_sp", remat_policy="nothing",
)


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--steps", type=int, default=300)
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--seq", type=int, default=128)
    ap.add_argument("--tiny", action="store_true",
                    help="smoke-scale model (fast CI)")
    ap.add_argument("--ckpt-dir", default="/tmp/repro_lm100m")
    args = ap.parse_args()
    cfg = LM_100M.reduced() if args.tiny else LM_100M
    print(f"model: {cfg.name} ({cfg.param_count()/1e6:.1f}M params)")
    out = train_loop(cfg, steps=args.steps, batch=args.batch, seq=args.seq,
                     ckpt_dir=args.ckpt_dir, ckpt_every=50)
    print(f"loss {out['first_loss']:.3f} -> {out['final_loss']:.3f} "
          f"({out['median_step_s']*1e3:.0f} ms/step, "
          f"{out['stragglers']} straggler steps)")


if __name__ == "__main__":
    main()
