"""Batched serving with vMCU ring KV caches: prefill a batch of prompts,
decode in lockstep; the sliding-window layers hold exactly `window` KV
slots in a circular buffer (the paper's pool, as a cache).

Run:  PYTHONPATH=src python examples/serve_lm.py [--arch gemma2-2b]
"""
import argparse
import time

import jax

from repro.configs import get_config
from repro.models.registry import build_model
from repro.serve.engine import ServingEngine


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="gemma2-2b")
    ap.add_argument("--batch", type=int, default=4)
    ap.add_argument("--prompt-len", type=int, default=48)
    ap.add_argument("--max-new", type=int, default=24)
    args = ap.parse_args()

    cfg = get_config(args.arch).reduced()  # CPU-sized, same architecture
    model = build_model(cfg)
    params = model.init(jax.random.PRNGKey(0))
    engine = ServingEngine(model, params,
                           cache_len=args.prompt_len + args.max_new + 8)
    prompts = [[(13 * i + j) % cfg.vocab for j in range(args.prompt_len)]
               for i in range(args.batch)]
    t0 = time.time()
    outs = engine.generate(prompts, max_new=args.max_new)
    dt = time.time() - t0
    n = args.batch * args.max_new
    print(f"{args.arch}: window={cfg.window} ring slots per local layer")
    print(f"generated {n} tokens in {dt:.2f}s ({n/dt:.1f} tok/s)")
    print(f"sample: {outs[0][:10]}")


if __name__ == "__main__":
    main()
