"""Reproduce the paper's deployment story end-to-end:

Can MCUNet-320KB-ImageNet run on a 128 KB STM32-F411RE?  TinyEngine: no
(247.8 KB bottleneck).  HMCOS: no.  vMCU: yes.

Verdicts are computed from the whole-network graph compiler
(``repro.graph``): the net is scheduled, fused by the paper's exclusion
rule and planned into ONE VirtualPool ring; the legacy closed-form
module formulas are asserted as a cross-check.  Pass ``--execute`` to
also run the planned NetProgram through the SegmentPool clobber oracle
and the jnp ring backend against the plain-XLA reference.

Run:  PYTHONPATH=src python examples/mcu_plan.py [--ram-kb 128] [--execute]
"""
import argparse

from repro.core.graph_planner import (MCUNET_320KB_IMAGENET,
                                      MCUNET_5FPS_VWW, hmcos_module_bytes,
                                      tinyengine_module_bytes,
                                      vmcu_module_bytes)
from repro.graph import build_mcunet, plan_net


def deploy(net, name: str, num_classes: int, ram: int,
           execute: bool) -> None:
    graph = build_mcunet(net, name, num_classes=num_classes)
    plan = plan_net(graph)

    # The old closed-form numbers, now cross-checks of the graph path.
    assert plan.mcu_bottleneck_bytes == max(vmcu_module_bytes(c)
                                            for c in net)
    assert plan.tinyengine_bottleneck_bytes == max(
        tinyengine_module_bytes(c) for c in net)
    assert plan.hmcos_bottleneck_bytes == max(hmcos_module_bytes(c)
                                              for c in net)

    print(f"\n{name} on a {ram//1000} KB device "
          f"({len(plan.program.ops)} ops in one ring):")
    for label, b in (("vMCU", plan.mcu_bottleneck_bytes),
                     ("TinyEngine", plan.tinyengine_bottleneck_bytes),
                     ("HMCOS", plan.hmcos_bottleneck_bytes)):
        verdict = "DEPLOYABLE" if b <= ram else "out of memory"
        print(f"  {label:11s} bottleneck {b/1000:7.1f} KB -> {verdict}")
    bot = plan.bottleneck_group()
    print(f"  (vMCU bottleneck module: {bot.name}; reduction vs TinyEngine "
          f"{100 * plan.reduction_vs_tinyengine:.1f}%)")

    if execute:
        import jax
        import numpy as np

        from repro.graph import (certify_net, init_net_params,
                                 reference_forward, run_net)
        sim = certify_net(plan)
        print(f"  sim oracle: zero clobbers over {sim.reads} reads / "
              f"{sim.writes} writes (peak {sim.peak_live} of "
              f"{plan.program.n_segments} segments)")
        params = init_net_params(plan)
        x = jax.random.normal(jax.random.PRNGKey(0),
                              (plan.program.in_rows, plan.program.in_dim))
        y = run_net(plan, x, params, backend="jnp")
        ref = reference_forward(plan, x, params)
        err = float(np.abs(np.asarray(y) - np.asarray(ref)).max())
        print(f"  jnp ring execution matches plain-XLA reference "
              f"(max |err| = {err:.2e})")


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--ram-kb", type=int, default=128)
    ap.add_argument("--execute", action="store_true",
                    help="also run the NetPrograms (sim oracle + jnp)")
    args = ap.parse_args()
    ram = args.ram_kb * 1000
    deploy(MCUNET_5FPS_VWW, "MCUNet-5fps-VWW", 2, ram, args.execute)
    deploy(MCUNET_320KB_IMAGENET, "MCUNet-320KB-ImageNet", 1000, ram,
           args.execute)


if __name__ == "__main__":
    main()
