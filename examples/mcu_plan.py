"""Reproduce the paper's deployment story end-to-end — one call:

Can MCUNet-320KB-ImageNet run on a 128 KB STM32-F446RE?  TinyEngine: no
(247.8 KB bottleneck).  HMCOS: no.  vMCU: yes.

``repro.compile(net, target)`` runs the whole flow (build -> schedule ->
plan -> budget -> certify); the legacy closed-form module formulas are
asserted as a cross-check of the compiled plan.  Pass ``--execute`` to
also run the planned net on the jnp ring backend against the plain-XLA
reference, and ``--target`` to gate against another registered board.

Run:  PYTHONPATH=src python examples/mcu_plan.py [--target cortex-m4]
          [--execute] [--save-dir out/]
"""
import argparse

import repro
from repro.core.graph_planner import (MCUNET_320KB_IMAGENET,
                                      MCUNET_5FPS_VWW, hmcos_module_bytes,
                                      tinyengine_module_bytes,
                                      vmcu_module_bytes)

NETS = {"mcunet-5fps-vww": MCUNET_5FPS_VWW,
        "mcunet-320kb-imagenet": MCUNET_320KB_IMAGENET}


def deploy(name: str, target, execute: bool, save_dir: str | None) -> None:
    cn = repro.compile(name, target=target, dtype="float32",
                       certify=execute, check_budget=False)
    plan, modules = cn.plan, NETS[name]

    # The old closed-form numbers, now cross-checks of the compiled plan.
    assert plan.mcu_bottleneck_bytes == max(vmcu_module_bytes(c)
                                            for c in modules)
    assert plan.tinyengine_bottleneck_bytes == max(
        tinyengine_module_bytes(c) for c in modules)
    assert plan.hmcos_bottleneck_bytes == max(hmcos_module_bytes(c)
                                              for c in modules)

    rep = cn.report()
    ram = cn.target.sram_bytes
    print(f"\n{name} on {cn.target.cpu} ({ram // 1000} KB SRAM, "
          f"{rep['n_ops']} ops in one ring):")
    for label, b in (("vMCU", plan.mcu_bottleneck_bytes),
                     ("TinyEngine", plan.tinyengine_bottleneck_bytes),
                     ("HMCOS", plan.hmcos_bottleneck_bytes)):
        verdict = "DEPLOYABLE" if b <= ram else "out of memory"
        print(f"  {label:11s} bottleneck {b/1000:7.1f} KB -> {verdict}")
    print(f"  (vMCU bottleneck module: {rep['bottleneck_group']}; "
          f"reduction vs TinyEngine "
          f"{100 * rep['reduction_vs_tinyengine']:.1f}%)")

    if execute:
        import numpy as np
        import jax

        from repro.graph import reference_forward

        cert = cn.certificate
        print(f"  sim oracle: zero clobbers over {cert['reads']} reads / "
              f"{cert['writes']} writes (peak {cert['peak_live']} of "
              f"{cert['n_segments']} segments)")
        x = jax.random.normal(jax.random.PRNGKey(0),
                              (cn.program.in_rows, cn.program.in_dim))
        y = cn.run(x, backend="jnp")
        ref = reference_forward(cn.program, x, cn.ensure_params())
        err = float(np.abs(np.asarray(y) - np.asarray(ref)).max())
        print(f"  jnp ring execution matches plain-XLA reference "
              f"(max |err| = {err:.2e})")

    if save_dir:
        import pathlib

        out = pathlib.Path(save_dir)
        out.mkdir(parents=True, exist_ok=True)
        path = cn.save(str(out / f"{name}.plan.json"))
        print(f"  plan artifact -> {path} (repro.load() re-runs nothing)")


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--target", default="cortex-m4",
                    help=f"one of {repro.list_targets()}")
    ap.add_argument("--execute", action="store_true",
                    help="also run the compiled nets (sim oracle + jnp)")
    ap.add_argument("--save-dir", default=None,
                    help="write .plan.json artifacts here")
    args = ap.parse_args()
    for name in NETS:
        deploy(name, args.target, args.execute, args.save_dir)


if __name__ == "__main__":
    main()
