"""Reproduce the paper's deployment story end-to-end:

Can MCUNet-320KB-ImageNet run on a 128 KB STM32-F411RE?  TinyEngine: no
(247.8 KB bottleneck).  HMCOS: no.  vMCU: yes.

Run:  PYTHONPATH=src python examples/mcu_plan.py [--ram-kb 128]
"""
import argparse

from repro.core.graph_planner import (MCUNET_320KB_IMAGENET,
                                      MCUNET_5FPS_VWW, hmcos_module_bytes,
                                      tinyengine_module_bytes,
                                      vmcu_module_bytes)


def deploy(net, name: str, ram: int) -> None:
    rows = [(c.name, vmcu_module_bytes(c), tinyengine_module_bytes(c),
             hmcos_module_bytes(c)) for c in net]
    bv = max(r[1] for r in rows)
    bt = max(r[2] for r in rows)
    bh = max(r[3] for r in rows)
    print(f"\n{name} on a {ram//1000} KB device:")
    for label, b in (("vMCU", bv), ("TinyEngine", bt), ("HMCOS", bh)):
        verdict = "DEPLOYABLE" if b <= ram else "out of memory"
        print(f"  {label:11s} bottleneck {b/1000:7.1f} KB -> {verdict}")
    mod = max(rows, key=lambda r: r[1])
    print(f"  (vMCU bottleneck module: {mod[0]}; reduction vs TinyEngine "
          f"{100 * (1 - bv / bt):.1f}%)")


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--ram-kb", type=int, default=128)
    args = ap.parse_args()
    ram = args.ram_kb * 1000
    deploy(MCUNET_5FPS_VWW, "MCUNet-5fps-VWW", ram)
    deploy(MCUNET_320KB_IMAGENET, "MCUNet-320KB-ImageNet", ram)


if __name__ == "__main__":
    main()
