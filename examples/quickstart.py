"""Quickstart: vMCU's segment-level memory management in five minutes.

1. Solve the paper's Eq. (1) for a fully-connected layer (exact ILP optimum
   via lexicographic scan + closed form).
2. Execute the layer *inside* a circular segment pool at that offset —
   first in the byte-exact simulator, then as the Pallas ring-GEMM kernel
   (interpret mode on CPU, Mosaic on TPU).
3. Run a whole FC chain through one donated ring buffer in jitted JAX and
   compare against the naive chain: same numerics, smaller footprint.

Run:  PYTHONPATH=src python examples/quickstart.py
"""
import jax
import jax.numpy as jnp
import numpy as np

from repro.core import (SegmentPool, motivational_example, plan_chain,
                        plan_gemm, run_gemm_schedule)
from repro.core.ring_buffer import (init_chain_params, naive_chain_apply,
                                    run_chain_via_ring)
from repro.kernels import ops
from repro.kernels import ref as kref

print("=== 1. Eq. (1): plan a fully-connected layer ===")
seg_pool, tensor_pool = motivational_example()
print(f"paper Fig. 1(c): segment-level pool = {seg_pool} segments, "
      f"tensor-level = {tensor_pool}  (paper says 7 vs 10)")

M, N, K = 8, 4, 6  # in segments
plan = plan_gemm(M, N, K, segment_bytes=128, validate=True)
print(f"GEMM [{M}x{K}]@[{K}x{N}]: delta = {plan.delta} segments, pool = "
      f"{plan.pool_segments} vs naive {plan.naive_segments} "
      f"({100 * plan.saving_fraction:.1f}% saved)")

print("\n=== 2. Execute in the circular pool (simulator) ===")
pool = SegmentPool(plan.pool_segments, plan.segment_bytes)
run_gemm_schedule(pool, M, N, K, b_out=0, b_in=plan.delta)
print(f"schedule OK: peak live = {pool.peak_live} segments "
      f"({pool.reads} reads, {pool.writes} writes) — no clobbers")

print("\n=== 3. Pallas ring-GEMM kernel (vMCU Fig. 4 on TPU) ===")
key = jax.random.PRNGKey(0)
x = jax.random.normal(key, (128, 384), jnp.float32)
w = jax.random.normal(key, (384, 256), jnp.float32) / 16
y, info = ops.segment_gemm(x, w)
err = float(jnp.max(jnp.abs(y - kref.gemm_ref(x, w, jnp.zeros(256)))))
print(f"kernel vs oracle max err = {err:.2e}; pool {info['pool_bytes']} B "
      f"vs naive {info['naive_bytes']} B "
      f"({100 * (1 - info['pool_bytes'] / info['naive_bytes']):.1f}% saved)")

print("\n=== 4. Whole chain in ONE donated ring buffer ===")
dims = [512, 2048, 512, 256]
m = 32
chain_plan = plan_chain(m, dims)
params = init_chain_params(key, dims)
x = jax.random.normal(key, (m, dims[0]))
y_ring = run_chain_via_ring(x, params, chain_plan, block_rows=8)
y_ref = naive_chain_apply(x, params)
np.testing.assert_allclose(np.asarray(y_ring), np.asarray(y_ref),
                           rtol=3e-5, atol=3e-5)
print(f"chain {dims}: ring pool {chain_plan.pool_bytes/1e3:.0f} KB vs "
      f"naive {chain_plan.naive_bytes/1e3:.0f} KB "
      f"({100*(1-chain_plan.pool_bytes/chain_plan.naive_bytes):.1f}% saved), "
      "numerics identical")
