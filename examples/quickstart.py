"""Quickstart: vMCU's segment-level memory management in five minutes.

1. Solve the paper's Eq. (1) for a fully-connected layer (exact ILP optimum
   via lexicographic scan + closed form).
2. Execute the layer *inside* a circular segment pool at that offset in the
   byte-exact simulator.
3. The unified API: ``plan_program`` one multi-op plan (gemm chain + fused
   MLP) over a single ``VirtualPool`` and ``execute`` the SAME plan object
   on all three backends — ``sim`` (clobber oracle), ``jnp`` (jitted ring
   scans), ``pallas`` (TPU kernels; interpret mode on CPU).
4. Legacy chain adapter: the original ``plan_chain`` API still works and is
   now a thin wrapper over ``plan_program``.

Run:  PYTHONPATH=src python examples/quickstart.py
"""
import jax
import jax.numpy as jnp
import numpy as np

from repro.core import (FusedMLPSpec, GemmSpec, SegmentPool, execute,
                        motivational_example, plan_chain, plan_gemm,
                        plan_program, run_gemm_schedule, run_program)
from repro.core.ring_buffer import (init_chain_params, naive_chain_apply,
                                    run_chain_via_ring)
from repro.kernels import ref as kref

print("=== 1. Eq. (1): plan a fully-connected layer ===")
seg_pool, tensor_pool = motivational_example()
print(f"paper Fig. 1(c): segment-level pool = {seg_pool} segments, "
      f"tensor-level = {tensor_pool}  (paper says 7 vs 10)")

M, N, K = 8, 4, 6  # in segments
plan = plan_gemm(M, N, K, segment_bytes=128, validate=True)
print(f"GEMM [{M}x{K}]@[{K}x{N}]: delta = {plan.delta} segments, pool = "
      f"{plan.pool_segments} vs naive {plan.naive_segments} "
      f"({100 * plan.saving_fraction:.1f}% saved)")

print("\n=== 2. Execute in the circular pool (simulator) ===")
pool = SegmentPool(plan.pool_segments, plan.segment_bytes)
run_gemm_schedule(pool, M, N, K, b_out=0, b_in=plan.delta)
print(f"schedule OK: peak live = {pool.peak_live} segments "
      f"({pool.reads} reads, {pool.writes} writes) — no clobbers")

print("\n=== 3. One PoolProgram, three backends ===")
m, dims, d_ff = 16, [256, 384, 256], 512
program = plan_program(m, dims[0],
                       [GemmSpec(dims[1], activation="gelu"),
                        GemmSpec(dims[2]),
                        FusedMLPSpec(d_ff, ff_tile=256)],
                       block_rows=8)
print(f"program: {[op.kind for op in program.ops]} — tight pool "
      f"{program.pool_bytes} B vs naive {program.naive_bytes} B "
      f"({100 * program.saving_fraction:.1f}% saved); physical ring "
      f"{program.physical_pool_bytes} B (DMA block padding)")

key = jax.random.PRNGKey(0)
ks = jax.random.split(key, 8)
params = [
    (jax.random.normal(ks[0], (dims[0], dims[1])) / 16,
     jax.random.normal(ks[1], (dims[1],))),
    (jax.random.normal(ks[2], (dims[1], dims[2])) / 19,
     jax.random.normal(ks[3], (dims[2],))),
    (jax.random.normal(ks[4], (dims[2], d_ff)) / 16,
     jax.random.normal(ks[5], (dims[2], d_ff)) / 16,
     jax.random.normal(ks[6], (d_ff, dims[2])) / 22),
]
x = jax.random.normal(ks[7], (m, dims[0]))

sim = execute(program, backend="sim")  # clobber oracle: raises if unsafe
print(f"sim backend: clobber-free, peak live {sim.peak_live}/"
      f"{program.n_segments} segments, {sim.reads} reads")

y_jnp, _ = run_program(program, x, params, backend="jnp")
y_pal, _ = run_program(program, x, params, backend="pallas")
np.testing.assert_allclose(np.asarray(y_jnp), np.asarray(y_pal),
                           rtol=1e-5, atol=1e-5)
h = jax.nn.gelu(kref.gemm_ref(x, *params[0]))
h = kref.gemm_ref(h, *params[1])
want = kref.fused_mlp_ref(h, *params[2])
err = float(jnp.max(jnp.abs(y_jnp - want)))
print(f"jnp == pallas from the same plan object; max err vs oracle "
      f"{err:.2e}")

print("\n=== 4. Legacy chain API (now an adapter over plan_program) ===")
dims = [512, 2048, 512, 256]
m = 32
chain_plan = plan_chain(m, dims)
params = init_chain_params(key, dims)
x = jax.random.normal(key, (m, dims[0]))
y_ring = run_chain_via_ring(x, params, chain_plan, block_rows=8)
y_ref = naive_chain_apply(x, params)
np.testing.assert_allclose(np.asarray(y_ring), np.asarray(y_ref),
                           rtol=3e-5, atol=3e-5)
print(f"chain {dims}: ring pool {chain_plan.pool_bytes/1e3:.0f} KB vs "
      f"naive {chain_plan.naive_bytes/1e3:.0f} KB "
      f"({100*(1-chain_plan.pool_bytes/chain_plan.naive_bytes):.1f}% saved), "
      "numerics identical")
